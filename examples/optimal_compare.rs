//! GUS vs the exact optimum — the paper's in-text validation ("GUS
//! achieves on average 90% of the optimal value", computed there with
//! CPLEX 12.10; here with the in-tree branch-and-bound solver).
//!
//! Run with: `cargo run --release --example optimal_compare [--instances N]`

use edgeus::figures::run_optimal_gap;
use edgeus::util::cli::Args;

fn main() {
    let args = Args::from_env(false);
    let instances = args.get_usize("instances", 15);
    let seed = args.get_u64("seed", 7);
    let sizes: Vec<usize> = args
        .get_list("sizes")
        .map(|v| v.iter().map(|s| s.parse().unwrap_or(6)).collect())
        .unwrap_or_else(|| vec![3, 5, 8, 10, 12]);

    eprintln!("solving {} instances per size {:?} to proven optimality...", instances, sizes);
    let result = run_optimal_gap(&sizes, instances, seed);
    println!("\n# GUS vs exact optimum (branch-and-bound)\n");
    println!("{}", result.series.to_markdown());
    println!(
        "mean GUS/OPT ratio: {:.3}   (paper: ~0.90 with CPLEX)\n\
         proven-exact solves: {:.1}%",
        result.mean_ratio,
        100.0 * result.exact_fraction
    );
    assert!(result.mean_ratio > 0.85, "greedy fell below the paper's band");
    println!("\nGUS is within the paper's near-optimality band ✓");
}
