//! End-to-end serving driver — the full-stack proof that all three layers
//! compose: rust coordinator (L3) → PJRT runtime → AOT-compiled EdgeNet
//! HLO (L2) built on the Pallas GEMM kernel (L1).
//!
//! Recreates the paper's testbed experiment live: two edge servers +
//! one cloud, bounded admission queues, 3000 ms decision frames, GUS
//! decisions, simulated wireless links with the paper's bandwidth
//! estimator — and **real model inference for every served request**.
//! Reports satisfaction, the decision mix, and latency/throughput.
//!
//! Requires `make artifacts`. Run with:
//! `cargo run --release --example testbed_serving [--requests N] [--scale S]`

use edgeus::serving::{ServingConfig, ServingSystem};
use edgeus::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(false);
    let mut cfg = ServingConfig::default();
    cfg.artifacts_dir = args.get_or("artifacts", "artifacts").to_string();
    cfg.total_requests = args.get_usize("requests", 240);
    cfg.time_scale = args.get_f64("scale", 50.0);
    cfg.seed = args.get_u64("seed", 7);

    println!(
        "testbed: {} edge + 1 cloud, {} requests over {:.0} s (sim), frame {:.0} ms, \
         queue cap {}, deadline {:.0} ms, min accuracy {:.0}%",
        cfg.num_edge,
        cfg.total_requests,
        cfg.window_ms / 1e3,
        cfg.frame_ms,
        cfg.queue_capacity,
        cfg.deadline_ms,
        cfg.min_accuracy_pct,
    );
    println!("policies: gus vs local-all vs offload-all (same seed, same workload)\n");

    for policy in ["gus", "local-all", "offload-all"] {
        let mut c = cfg.clone();
        c.scheduler = policy.to_string();
        let t0 = std::time::Instant::now();
        let m = ServingSystem::new(c)?.run()?;
        println!("## {policy}  (wall {:.1}s real)\n", t0.elapsed().as_secs_f64());
        println!("{}", m.summary_markdown());
    }
    println!(
        "expected shape (paper Fig. 1e): GUS satisfies the most users; local-all is\n\
         bounded by edge compute (γ); offload-all by the edge uplink budget (η)."
    );
    Ok(())
}
