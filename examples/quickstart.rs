//! Quickstart: the five-minute tour of the edgeus public API.
//!
//! 1. Build a paper-default MUS instance (9 edge servers + 1 cloud,
//!    100 requests, 100 services × 10 model tiers).
//! 2. Schedule it with GUS and with every baseline; compare satisfaction.
//! 3. Validate the GUS schedule against the full ILP constraint set.
//! 4. If `artifacts/` is built, run one real EdgeNet inference through
//!    the PJRT runtime.
//!
//! Run with: `cargo run --release --example quickstart`

use edgeus::coordinator::us::{validate_schedule, ConstraintMode};
use edgeus::prelude::*;

fn main() -> anyhow::Result<()> {
    // ----- 1. a problem instance ---------------------------------------
    let mut rng = Rng::new(42);
    let scenario = ScenarioParams::default();
    let inst = build_instance(&scenario, &mut rng);
    println!(
        "instance: {} requests, {} servers ({} edge + {} cloud), {} services x {} tiers",
        inst.num_requests(),
        inst.num_servers(),
        inst.topology.edge_ids().len(),
        inst.topology.cloud_ids().len(),
        inst.catalog.num_services,
        inst.catalog.num_tiers,
    );

    // ----- 2. schedule with every policy --------------------------------
    println!("\n| policy | satisfied % | served % | objective | mix local/cloud/peer/drop |");
    println!("|---|---|---|---|---|");
    for sched in all_schedulers() {
        let schedule = sched.schedule(&inst, &mut rng.fork(1));
        let mix = schedule.decision_mix_pct(&inst);
        println!(
            "| {} | {:.1} | {:.1} | {:.4} | {:.0}/{:.0}/{:.0}/{:.0} |",
            sched.name(),
            schedule.satisfied_pct(&inst),
            100.0 * schedule.served() as f64 / inst.num_requests() as f64,
            schedule.objective(),
            mix[0],
            mix[1],
            mix[2],
            mix[3],
        );
    }

    // ----- 3. validate the GUS schedule ---------------------------------
    let gus = Gus::default().schedule(&inst, &mut rng.fork(2));
    validate_schedule(&inst, &gus, ConstraintMode::STRICT)
        .map_err(|e| anyhow::anyhow!("GUS schedule violates the ILP constraints: {e}"))?;
    println!("\nGUS schedule validated against constraints (2a)-(2f) ✓");

    // ----- 4. real inference through PJRT (optional) ---------------------
    match edgeus::runtime::InferenceEngine::load_filtered("artifacts", |a| {
        a.tier == "tiny" && a.batch == 1
    }) {
        Ok(engine) => {
            let images = vec![0.5f32; 32 * 32 * 3];
            let result = engine.infer_tier("tiny", 1, &images)?;
            println!(
                "real EdgeNet-tiny inference on {}: class={} in {:.2} ms",
                engine.platform(),
                result.predictions()[0],
                result.execute_ms
            );
        }
        Err(_) => {
            println!("(skip PJRT demo — run `make artifacts` first)");
        }
    }
    Ok(())
}
