//! Numerical study: regenerate the paper's Fig. 1(a)–(d) series at a
//! CI-friendly scale and check the qualitative claims hold:
//!
//! * (a) satisfaction rises with the requested-delay budget;
//! * (b) satisfaction falls as requested accuracy rises;
//! * (c) satisfaction falls as offered load rises;
//! * (d) satisfaction falls as queue delay rises;
//! * GUS dominates the naive baselines everywhere.
//!
//! Run with: `cargo run --release --example numerical_study [--runs N]`
//! (full-scale regeneration: `cargo bench --bench fig1_numerical` or
//! `edgeus figure --id fig1a --runs 2000`).

use edgeus::figures::{run_numerical, NumericalConfig, NumericalFigure};
use edgeus::util::cli::Args;

fn main() {
    let args = Args::from_env(false);
    let mut cfg = NumericalConfig::default();
    cfg.runs = args.get_usize("runs", 60);
    cfg.seed = args.get_u64("seed", 7);

    for figure in [
        NumericalFigure::Fig1a,
        NumericalFigure::Fig1b,
        NumericalFigure::Fig1c,
        NumericalFigure::Fig1d,
    ] {
        eprintln!("running {} ({} MC runs per point)...", figure.id(), cfg.runs);
        let series = run_numerical(figure, &cfg);
        println!("\n# {} — satisfied users (%) vs {}\n", figure.id(), series.x_label);
        println!("{}", series.to_markdown());

        // Qualitative checks (the paper's claims).
        let gus = &series.policies.iter().find(|(n, _, _)| n == "gus").unwrap().1;
        let first = gus.first().copied().unwrap_or(0.0);
        let last = gus.last().copied().unwrap_or(0.0);
        let trend_ok = match figure {
            NumericalFigure::Fig1a => last > first,
            _ => last < first,
        };
        println!(
            "trend check ({}): GUS goes {:.1}% -> {:.1}% … {}",
            figure.id(),
            first,
            last,
            if trend_ok { "matches the paper ✓" } else { "DOES NOT match ✗" }
        );
        for baseline in ["random", "offload-all", "local-all"] {
            let b = &series.policies.iter().find(|(n, _, _)| n == baseline).unwrap().1;
            let wins = gus.iter().zip(b.iter()).filter(|(g, b)| g >= b).count();
            println!("  GUS ≥ {baseline} on {wins}/{} sweep points", gus.len());
        }
    }
}
