"""AOT compile path: lower EdgeNet tiers to HLO text for the rust runtime.

Run once at build time (``make artifacts``); Python is never on the request
path. Interchange format is HLO **text**, not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (what the published ``xla`` 0.1.6 crate binds) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Outputs, under ``artifacts/``:
  * ``edgenet_{tier}_b{batch}.hlo.txt`` — one self-contained module per
    (tier, batch); parameters are baked in as constants so the rust side
    feeds ``f32[batch,32,32,3]`` images only and reads ``f32[batch,10]``
    logits (wrapped in a 1-tuple: lowered with ``return_tuple=True``).
  * ``manifest.json`` — inventory consumed by ``rust/src/runtime``:
    input/output shapes, tier profiles (accuracy %, params, FLOPs), and
    the L1 kernel's VMEM-footprint / MXU-utilization estimates for the
    DESIGN.md §Perf bookkeeping.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import jax

from compile import model
from compile.kernels import matmul

DEFAULT_BATCHES = (1, 4, 8)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe route)."""
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants: the default printer elides big literals as
    # `constant({...})`, which would silently destroy the baked-in params.
    text = comp.as_hlo_text(print_large_constants=True)
    if "{...}" in text:
        raise RuntimeError("HLO text still contains elided constants")
    return text


def lower_tier(tier: str, batch: int) -> str:
    fn, spec = model.serving_fn(tier, batch)
    return to_hlo_text(jax.jit(fn).lower(spec))


def build(out_dir: str, tiers, batches, verbose: bool = True) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {
        "format": "hlo-text",
        "image_size": model.IMAGE_SIZE,
        "image_channels": model.IMAGE_CHANNELS,
        "num_classes": model.NUM_CLASSES,
        "param_seed": model.PARAM_SEED,
        "kernel": {
            "name": "matmul_bias_act",
            "block": [matmul.DEFAULT_BLOCK_M, matmul.DEFAULT_BLOCK_N, matmul.DEFAULT_BLOCK_K],
            "vmem_footprint_bytes": matmul.vmem_footprint_bytes(),
        },
        "artifacts": [],
    }
    for tier in tiers:
        spec = model.TIERS[tier]
        for batch in batches:
            name = f"edgenet_{tier}_b{batch}"
            path = os.path.join(out_dir, f"{name}.hlo.txt")
            text = lower_tier(tier, batch)
            with open(path, "w") as f:
                f.write(text)
            entry = {
                "name": name,
                "tier": tier,
                "batch": batch,
                "file": os.path.basename(path),
                "input_shape": [batch, model.IMAGE_SIZE, model.IMAGE_SIZE, model.IMAGE_CHANNELS],
                "output_shape": [batch, model.NUM_CLASSES],
                "profile_accuracy_pct": spec.profile_accuracy,
                "params": model.param_count(tier),
                "flops_per_image": model.flops_per_image(tier),
                "sha256": hashlib.sha256(text.encode()).hexdigest(),
                "bytes": len(text),
            }
            manifest["artifacts"].append(entry)
            if verbose:
                print(f"  wrote {path} ({len(text)/1e6:.2f} MB)", file=sys.stderr)
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--tiers", default=",".join(model.TIERS), help="comma list")
    ap.add_argument("--batches", default=",".join(map(str, DEFAULT_BATCHES)))
    args = ap.parse_args()
    tiers = [t for t in args.tiers.split(",") if t]
    batches = [int(b) for b in args.batches.split(",") if b]
    m = build(args.out, tiers, batches)
    print(f"wrote {len(m['artifacts'])} artifacts + manifest.json to {args.out}")


if __name__ == "__main__":
    main()
