"""L2 — EdgeNet: the JAX model family served by the coordinator.

The paper serves image-classification requests with |L| DL-model tiers per
service, trading accuracy for latency (SqueezeNet on the edge, GoogleNet on
the cloud). Pretrained ImageNet weights are not available offline, so we
build **EdgeNet**, a CNN family whose tiers scale width/depth the same way
(see DESIGN.md §Substitutions): the scheduler only consumes each tier's
(accuracy, latency, cost) *profile*, while the serving path executes the
real network below through PJRT.

Every FLOP goes through the L1 Pallas kernel: convolutions are lowered to
im2col GEMMs and dense layers are plain GEMMs, all via
``kernels.matmul_bias_act``. A structurally independent reference forward
pass built on ``kernels/ref.py`` backs the pytest oracle checks.

Build-time only — lowered to HLO text by ``aot.py``; never imported at
request time.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from compile.kernels import matmul, ref

IMAGE_SIZE = 32
IMAGE_CHANNELS = 3
NUM_CLASSES = 10
PARAM_SEED = 20200731  # fixed: artifacts bake params in as constants


@dataclasses.dataclass(frozen=True)
class TierSpec:
    """Architecture of one EdgeNet accuracy tier.

    ``conv_widths`` is a list of stages; each stage is a list of 3x3 VALID
    conv output widths followed by a 2x2 average pool. A dense trunk
    (``dense_widths``) and the 10-way classifier head follow.
    """

    name: str
    conv_stages: Tuple[Tuple[int, ...], ...]
    dense_widths: Tuple[int, ...]
    # Calibrated top-1 accuracy profile (%) exposed to the scheduler —
    # spans the SqueezeNet-class .. GoogleNet-class spread the paper uses.
    profile_accuracy: float


# Tier ladder: monotone in parameters, FLOPs and profile accuracy. The
# numerical experiments use |L|=10 synthetic tiers (rust side interpolates
# profiles); these four are the tiers with *real* compiled artifacts.
TIERS: Dict[str, TierSpec] = {
    "tiny": TierSpec("tiny", ((8,), (16,)), (), 40.0),
    "small": TierSpec("small", ((16,), (32,)), (64,), 52.0),
    "base": TierSpec("base", ((32,), (64, 64)), (128,), 63.0),
    "large": TierSpec("large", ((48, 48), (96, 96)), (256,), 71.0),
}

Params = Dict[str, jax.Array]


def _glorot(key, shape):
    fan_in = int(jnp.prod(jnp.asarray(shape[:-1])))
    fan_out = shape[-1]
    scale = jnp.sqrt(2.0 / (fan_in + fan_out))
    return scale * jax.random.normal(key, shape, dtype=jnp.float32)


def _layer_shapes(spec: TierSpec) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, weight-shape) list; biases are the trailing dim."""
    shapes: List[Tuple[str, Tuple[int, ...]]] = []
    h = IMAGE_SIZE
    c = IMAGE_CHANNELS
    for si, stage in enumerate(spec.conv_stages):
        for ci, width in enumerate(stage):
            shapes.append((f"conv{si}_{ci}", (3, 3, c, width)))
            c = width
            h = h - 2  # 3x3 VALID
        h = h // 2  # 2x2 avg pool
    flat = h * h * c
    prev = flat
    for di, width in enumerate(spec.dense_widths):
        shapes.append((f"dense{di}", (prev, width)))
        prev = width
    shapes.append(("head", (prev, NUM_CLASSES)))
    return shapes


def init_params(tier: str, seed: int = PARAM_SEED) -> Params:
    """Deterministic parameters for ``tier`` (baked into artifacts)."""
    spec = TIERS[tier]
    params: Params = {}
    key = jax.random.PRNGKey(seed)
    for name, shape in _layer_shapes(spec):
        key, wk = jax.random.split(key)
        params[f"{name}_w"] = _glorot(wk, shape)
        params[f"{name}_b"] = jnp.zeros((shape[-1],), dtype=jnp.float32)
    return params


def param_count(tier: str) -> int:
    return sum(int(jnp.size(v)) for v in init_params(tier).values())


def flops_per_image(tier: str) -> int:
    """MAC-based FLOP estimate (2*MACs) for one forward pass."""
    spec = TIERS[tier]
    total = 0
    h = IMAGE_SIZE
    c = IMAGE_CHANNELS
    for stage in spec.conv_stages:
        for width in stage:
            oh = h - 2
            total += 2 * oh * oh * (3 * 3 * c) * width
            h, c = oh, width
        h = h // 2
    prev = h * h * c
    for width in list(spec.dense_widths) + [NUM_CLASSES]:
        total += 2 * prev * width
        prev = width
    return total


def _im2col(images: jax.Array, kh: int, kw: int, stride: int = 1) -> jax.Array:
    """Patch extraction for the kernel path, (kh, kw, C) row-major.

    Strided-slice construction: concatenate the kh*kw shifted views along
    a new patch axis. This lowers to plain slices + one concatenate —
    ~3.5x cheaper on the CPU backend than
    ``lax.conv_general_dilated_patches`` (which materializes an identity
    conv; see EXPERIMENTS.md §Perf, L2 iteration 2).
    """
    b, h, w, c = images.shape
    oh = (h - kh) // stride + 1
    ow = (w - kw) // stride + 1
    views = [
        images[:, i : i + stride * oh : stride, j : j + stride * ow : stride, :].reshape(
            b, oh, ow, 1, c
        )
        for i in range(kh)
        for j in range(kw)
    ]
    stacked = jnp.concatenate(views, axis=3)  # (B, OH, OW, kh*kw, C)
    return stacked.reshape(b * oh * ow, kh * kw * c)


def _conv_block(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    kh, kw, c, f = w.shape
    bsz, h, _, _ = x.shape
    oh = h - kh + 1
    cols = _im2col(x, kh, kw)
    out = matmul.matmul_bias_act(cols, w.reshape(kh * kw * c, f), b, activation="relu")
    return out.reshape(bsz, oh, oh, f)


def _avgpool(x: jax.Array, window: int = 2) -> jax.Array:
    b, h, w, c = x.shape
    oh, ow = h // window, w // window
    x = x[:, : oh * window, : ow * window, :]
    return x.reshape(b, oh, window, ow, window, c).mean(axis=(2, 4))


def forward(params: Params, images: jax.Array, tier: str) -> jax.Array:
    """EdgeNet forward pass (Pallas-kernel path): images -> logits.

    Args:
      params: from :func:`init_params`.
      images: ``(B, 32, 32, 3)`` f32 in [0, 1].
      tier: key into :data:`TIERS`.
    Returns:
      ``(B, 10)`` f32 logits.
    """
    spec = TIERS[tier]
    x = images
    for si, stage in enumerate(spec.conv_stages):
        for ci, _ in enumerate(stage):
            x = _conv_block(x, params[f"conv{si}_{ci}_w"], params[f"conv{si}_{ci}_b"])
        x = _avgpool(x)
    x = x.reshape(x.shape[0], -1)
    for di, _ in enumerate(spec.dense_widths):
        x = matmul.matmul_bias_act(
            x, params[f"dense{di}_w"], params[f"dense{di}_b"], activation="relu"
        )
    return matmul.matmul_bias_act(x, params["head_w"], params["head_b"])


def forward_ref(params: Params, images: jax.Array, tier: str) -> jax.Array:
    """Independent reference forward pass built purely on kernels/ref.py."""
    spec = TIERS[tier]
    x = images
    for si, stage in enumerate(spec.conv_stages):
        for ci, _ in enumerate(stage):
            x = ref.conv2d_ref(
                x,
                params[f"conv{si}_{ci}_w"],
                params[f"conv{si}_{ci}_b"],
                activation="relu",
            )
        x = ref.avgpool2d_ref(x, 2)
    x = x.reshape(x.shape[0], -1)
    for di, _ in enumerate(spec.dense_widths):
        x = ref.matmul_bias_act_ref(
            x, params[f"dense{di}_w"], params[f"dense{di}_b"], activation="relu"
        )
    return ref.matmul_bias_act_ref(x, params["head_w"], params["head_b"])


def serving_fn(tier: str, batch: int, seed: int = PARAM_SEED):
    """Close params over the forward pass: the AOT entrypoint.

    Returns a function of a single ``(batch, 32, 32, 3)`` input producing a
    1-tuple ``(logits,)`` — params are constants in the lowered HLO so the
    rust runtime feeds images only.
    """
    params = init_params(tier, seed)

    def fn(images: jax.Array):
        return (forward(params, images, tier),)

    return fn, jax.ShapeDtypeStruct((batch, IMAGE_SIZE, IMAGE_SIZE, IMAGE_CHANNELS), jnp.float32)
