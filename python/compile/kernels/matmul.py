"""L1 — Pallas tiled GEMM with fused bias + activation epilogue.

This is the compute hot-spot of every EdgeNet artifact: convolutions are
lowered to im2col GEMMs and dense layers are plain GEMMs, so all FLOPs in
the serving path flow through this kernel.

TPU mapping (see DESIGN.md §Hardware-Adaptation):
  * the (block_m, block_k) x (block_k, block_n) tile pair is sized for the
    MXU systolic array (128-multiples) and must fit VMEM together with the
    f32 accumulator tile;
  * the grid is (M/bm, N/bn, K/bk) with K innermost, so each output tile
    stays resident while K-panels stream HBM->VMEM (the BlockSpec index
    maps express the schedule a CUDA kernel would do with threadblocks);
  * accumulation is f32 regardless of input dtype; bias-add + activation
    are fused into the final K step to avoid an extra HBM round-trip.

`interpret=True` always: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel is lowered to plain HLO for both the pytest
oracle checks and the AOT artifacts consumed by the rust runtime.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile-size policy. `None` block arguments select VMEM-aware adaptive
# tiles via `auto_blocks`: the largest MXU-aligned tiles that keep the
# working set under the VMEM budget. Covering the whole K extent with one
# panel (when it fits) removes the K-accumulation grid dimension, which
# is both the TPU-optimal schedule for these EdgeNet shapes *and* the
# dominant cost in interpret mode (each K step is a serialized
# dynamic-update-slice round-trip; see EXPERIMENTS.md §Perf, L1
# iteration 1: 10.5 ms → 0.34 ms on the 784×432×48 conv GEMM).
DEFAULT_BLOCK_M = 128
DEFAULT_BLOCK_N = 128
DEFAULT_BLOCK_K = 128

# Budget for one grid step's VMEM working set (TPU cores have ~16 MiB;
# leave headroom for double-buffered input streams).
VMEM_BUDGET_BYTES = 12 * 1024 * 1024

MAX_BLOCK_M = 1024
MAX_BLOCK_N = 128
MAX_BLOCK_K = 4096


def auto_blocks(m: int, k: int, n: int) -> tuple:
    """Pick (block_m, block_n, block_k) for a GEMM of the given shape.

    Preference order: (1) cover K with a single panel so the output tile
    is written once (no accumulation revisits); (2) cover M; (3) keep N
    tiles at the 128-lane MXU width; all subject to the VMEM budget.
    """
    ceil8 = lambda v: ((v + 7) // 8) * 8  # noqa: E731
    bn = min(MAX_BLOCK_N, ceil8(n))
    bk = min(MAX_BLOCK_K, ceil8(k))
    bm = min(MAX_BLOCK_M, ceil8(m))
    # Shrink block_m (keeping K whole) until the working set fits.
    while bm > 128 and vmem_footprint_bytes(bm, bn, bk) > VMEM_BUDGET_BYTES:
        bm //= 2
    # If still over budget, fall back to shrinking K (re-enables the
    # accumulation grid, but stays correct).
    while bk > 128 and vmem_footprint_bytes(bm, bn, bk) > VMEM_BUDGET_BYTES:
        bk //= 2
    return bm, bn, bk

_ACTIVATIONS = ("none", "relu", "gelu")


def _apply_activation(x, activation: str):
    if activation == "relu":
        return jnp.maximum(x, 0.0)
    if activation == "gelu":
        return jax.nn.gelu(x)
    return x


def _matmul_kernel(x_ref, w_ref, b_ref, o_ref, *, k_steps: int, activation: str):
    """Grid = (m, n, k); K is innermost so o_ref acts as the accumulator."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == k_steps - 1)
    def _epilogue():
        out = o_ref[...] + b_ref[...].astype(jnp.float32)
        o_ref[...] = _apply_activation(out, activation)


def _pad_to(x, multiple: int, axis: int):
    size = x.shape[axis]
    rem = size % multiple
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, multiple - rem)
    return jnp.pad(x, pad)


@functools.partial(
    jax.jit,
    static_argnames=("activation", "block_m", "block_n", "block_k"),
)
def matmul_bias_act(
    x: jax.Array,
    w: jax.Array,
    b: Optional[jax.Array] = None,
    *,
    activation: str = "none",
    block_m: Optional[int] = None,
    block_n: Optional[int] = None,
    block_k: Optional[int] = None,
) -> jax.Array:
    """Compute ``act(x @ w + b)`` with a tiled Pallas kernel.

    Args:
      x: ``(M, K)`` array, f32 or bf16.
      w: ``(K, N)`` array, same dtype family as ``x``.
      b: optional ``(N,)`` bias; zeros when omitted.
      activation: one of ``none | relu | gelu`` (fused epilogue).
      block_*: tile sizes; inputs are zero-padded up to tile multiples and
        the result is sliced back, so ragged shapes are supported.

    Returns:
      ``(M, N)`` array in f32 (accumulation dtype).
    """
    if activation not in _ACTIVATIONS:
        raise ValueError(f"activation must be one of {_ACTIVATIONS}, got {activation!r}")
    if x.ndim != 2 or w.ndim != 2:
        raise ValueError(f"x and w must be rank-2, got {x.shape} @ {w.shape}")
    m, k = x.shape
    k2, n = w.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: {x.shape} @ {w.shape}")
    if b is None:
        b = jnp.zeros((n,), dtype=x.dtype)
    if b.shape != (n,):
        raise ValueError(f"bias must be ({n},), got {b.shape}")

    auto_m, auto_n, auto_k = auto_blocks(m, k, n)
    block_m = auto_m if block_m is None else block_m
    block_n = auto_n if block_n is None else block_n
    block_k = auto_k if block_k is None else block_k
    # Clamp tiles to the (padded) problem so tiny shapes don't waste work.
    block_m = min(block_m, _ceil_to(m, 8))
    block_n = min(block_n, _ceil_to(n, 8))
    block_k = min(block_k, _ceil_to(k, 8))

    xp = _pad_to(_pad_to(x, block_m, 0), block_k, 1)
    wp = _pad_to(_pad_to(w, block_k, 0), block_n, 1)
    bp = _pad_to(b.reshape(1, n), block_n, 1)

    mp, kp = xp.shape
    _, np_ = wp.shape
    grid = (mp // block_m, np_ // block_n, kp // block_k)

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=grid[2], activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, block_n), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls.
    )(xp, wp, bp)
    return out[:m, :n]


def _ceil_to(v: int, multiple: int) -> int:
    return ((v + multiple - 1) // multiple) * multiple


def vmem_footprint_bytes(
    block_m: int = DEFAULT_BLOCK_M,
    block_n: int = DEFAULT_BLOCK_N,
    block_k: int = DEFAULT_BLOCK_K,
    in_dtype_bytes: int = 4,
) -> int:
    """Static VMEM estimate for one grid step (used by DESIGN.md §Perf).

    x-tile + w-tile (input dtype) + output/accumulator tile (f32) + bias
    row, times 2 for double-buffered input streams.
    """
    tiles_in = (block_m * block_k + block_k * block_n) * in_dtype_bytes
    acc = block_m * block_n * 4
    bias = block_n * 4
    return 2 * tiles_in + acc + bias


def mxu_utilization_estimate(
    m: int,
    n: int,
    k: int,
    block_m: int = DEFAULT_BLOCK_M,
    block_n: int = DEFAULT_BLOCK_N,
    block_k: int = DEFAULT_BLOCK_K,
) -> float:
    """Fraction of MXU-issued MACs that are useful (non-padding) work."""
    mp, np_, kp = (_ceil_to(m, block_m), _ceil_to(n, block_n), _ceil_to(k, block_k))
    useful = m * n * k
    issued = mp * np_ * kp
    return useful / issued if issued else 0.0
