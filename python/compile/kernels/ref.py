"""Pure-jnp oracles for the Pallas kernels.

Everything in this module is deliberately boring: no tiling, no pallas, no
custom control flow — just the textbook expression of each op. pytest
compares the Pallas kernels against these under hypothesis-driven
shape/dtype sweeps, and the L2 reference model is built exclusively from
these functions so model-level tests have an independent numerics path.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def matmul_bias_act_ref(
    x: jax.Array,
    w: jax.Array,
    b: Optional[jax.Array] = None,
    *,
    activation: str = "none",
) -> jax.Array:
    """Reference ``act(x @ w + b)`` with f32 accumulation."""
    out = jnp.dot(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    if b is not None:
        out = out + b.astype(jnp.float32)
    if activation == "relu":
        out = jnp.maximum(out, 0.0)
    elif activation == "gelu":
        out = jax.nn.gelu(out)
    elif activation != "none":
        raise ValueError(f"unknown activation {activation!r}")
    return out


def im2col_ref(images: jax.Array, kh: int, kw: int, stride: int = 1) -> jax.Array:
    """Extract (kh, kw) patches: ``(B,H,W,C) -> (B*OH*OW, kh*kw*C)``.

    VALID padding; patch layout is (kh, kw, C) row-major, matching the
    im2col used by the L2 model.
    """
    b, h, w, c = images.shape
    oh = (h - kh) // stride + 1
    ow = (w - kw) // stride + 1
    cols = []
    for i in range(kh):
        for j in range(kw):
            patch = images[:, i : i + stride * oh : stride, j : j + stride * ow : stride, :]
            cols.append(patch.reshape(b, oh, ow, c))
    stacked = jnp.stack(cols, axis=3)  # (B, OH, OW, kh*kw, C)
    return stacked.reshape(b * oh * ow, kh * kw * c)


def conv2d_ref(
    images: jax.Array,
    filters: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    stride: int = 1,
    activation: str = "none",
) -> jax.Array:
    """Reference VALID conv: ``(B,H,W,C) * (kh,kw,C,F) -> (B,OH,OW,F)``."""
    kh, kw, c, f = filters.shape
    b, h, w, _ = images.shape
    oh = (h - kh) // stride + 1
    ow = (w - kw) // stride + 1
    cols = im2col_ref(images, kh, kw, stride)
    flat = matmul_bias_act_ref(
        cols, filters.reshape(kh * kw * c, f), bias, activation=activation
    )
    return flat.reshape(b, oh, ow, f)


def avgpool2d_ref(x: jax.Array, window: int) -> jax.Array:
    """Non-overlapping average pool over (B, H, W, C)."""
    b, h, w, c = x.shape
    oh, ow = h // window, w // window
    x = x[:, : oh * window, : ow * window, :]
    x = x.reshape(b, oh, window, ow, window, c)
    return x.mean(axis=(2, 4))
