"""L1 — Pallas kernels for the paper's compute hot-spot (EdgeNet GEMMs)."""

from compile.kernels.matmul import (  # noqa: F401
    matmul_bias_act,
    mxu_utilization_estimate,
    vmem_footprint_bytes,
)
from compile.kernels import ref  # noqa: F401
