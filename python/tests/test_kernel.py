"""L1 correctness: Pallas kernel vs pure-jnp oracle (the CORE signal).

hypothesis sweeps shapes and dtypes; dedicated cases pin down the ragged
edge tiles, block-size interactions, activations, and input validation.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul, ref

jax.config.update("jax_platform_name", "cpu")


def _rand(key, shape, dtype):
    return jax.random.normal(jax.random.PRNGKey(key), shape).astype(dtype)


def _check(x, w, b, activation, rtol=1e-5, atol=1e-5, **blocks):
    got = matmul.matmul_bias_act(x, w, b, activation=activation, **blocks)
    want = ref.matmul_bias_act_ref(x, w, b, activation=activation)
    assert got.shape == want.shape
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=rtol, atol=atol)


# ---------------------------------------------------------------- hypothesis

@settings(max_examples=40, deadline=None)
@given(
    m=st.integers(1, 300),
    k=st.integers(1, 300),
    n=st.integers(1, 300),
    activation=st.sampled_from(["none", "relu", "gelu"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_ref_f32(m, k, n, activation, seed):
    x = _rand(seed, (m, k), jnp.float32)
    w = _rand(seed + 1, (k, n), jnp.float32)
    b = _rand(seed + 2, (n,), jnp.float32)
    # Tiled K-accumulation reorders float adds vs the single-dot reference;
    # tolerance scales with sqrt(K) (values are ~N(0,1)).
    _check(x, w, b, activation, rtol=1e-4, atol=2e-5 * max(1.0, k) ** 0.5)


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 200),
    k=st.integers(1, 200),
    n=st.integers(1, 200),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_ref_bf16(m, k, n, seed):
    x = _rand(seed, (m, k), jnp.bfloat16)
    w = _rand(seed + 1, (k, n), jnp.bfloat16)
    b = _rand(seed + 2, (n,), jnp.bfloat16)
    # bf16 inputs, f32 accumulation: tolerance scales with K.
    _check(x, w, b, "none", rtol=5e-2, atol=5e-2 * max(1, k) ** 0.5)


@settings(max_examples=20, deadline=None)
@given(
    block_m=st.sampled_from([8, 16, 32, 128]),
    block_n=st.sampled_from([8, 16, 32, 128]),
    block_k=st.sampled_from([8, 16, 32, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_block_size_invariance(block_m, block_n, block_k, seed):
    """Result must not depend on the tiling."""
    x = _rand(seed, (70, 45), jnp.float32)
    w = _rand(seed + 1, (45, 33), jnp.float32)
    b = _rand(seed + 2, (33,), jnp.float32)
    _check(x, w, b, "relu", block_m=block_m, block_n=block_n, block_k=block_k)


# ------------------------------------------------------------------ pinned

@pytest.mark.parametrize("m,k,n", [
    (1, 1, 1),            # degenerate
    (128, 128, 128),      # exactly one tile
    (129, 128, 128),      # one ragged row tile
    (128, 129, 128),      # ragged K panel
    (128, 128, 129),      # ragged col tile
    (256, 384, 512),      # multi-tile, all aligned
    (7, 900, 3),          # deep-K skinny
    (900, 27, 8),         # conv-shaped (im2col of 32x32x3, 3x3, 8 filters)
])
def test_kernel_shape_cases(m, k, n):
    x = _rand(0, (m, k), jnp.float32)
    w = _rand(1, (k, n), jnp.float32)
    b = _rand(2, (n,), jnp.float32)
    _check(x, w, b, "relu", atol=1e-4, rtol=1e-4)


def test_kernel_no_bias_defaults_to_zero():
    x = _rand(0, (17, 19), jnp.float32)
    w = _rand(1, (19, 23), jnp.float32)
    got = matmul.matmul_bias_act(x, w)
    want = ref.matmul_bias_act_ref(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_kernel_relu_clamps_negatives():
    x = -jnp.ones((4, 4), jnp.float32)
    w = jnp.eye(4, dtype=jnp.float32)
    out = matmul.matmul_bias_act(x, w, activation="relu")
    assert np.all(np.asarray(out) == 0.0)


def test_kernel_zero_inputs_give_bias():
    x = jnp.zeros((5, 7), jnp.float32)
    w = jnp.ones((7, 3), jnp.float32)
    b = jnp.asarray([1.0, -2.0, 3.0], jnp.float32)
    out = matmul.matmul_bias_act(x, w, b)
    np.testing.assert_allclose(np.asarray(out), np.tile([1.0, -2.0, 3.0], (5, 1)))


def test_kernel_rejects_bad_activation():
    x = jnp.zeros((2, 2), jnp.float32)
    with pytest.raises(ValueError, match="activation"):
        matmul.matmul_bias_act(x, x, activation="tanh")


def test_kernel_rejects_rank_mismatch():
    with pytest.raises(ValueError, match="rank-2"):
        matmul.matmul_bias_act(jnp.zeros((2, 2, 2)), jnp.zeros((2, 2)))


def test_kernel_rejects_contraction_mismatch():
    with pytest.raises(ValueError, match="contraction"):
        matmul.matmul_bias_act(jnp.zeros((2, 3)), jnp.zeros((4, 2)))


def test_kernel_rejects_bad_bias_shape():
    with pytest.raises(ValueError, match="bias"):
        matmul.matmul_bias_act(jnp.zeros((2, 3)), jnp.zeros((3, 4)), jnp.zeros((5,)))


def test_kernel_deterministic():
    x = _rand(0, (50, 60), jnp.float32)
    w = _rand(1, (60, 40), jnp.float32)
    a = matmul.matmul_bias_act(x, w)
    b = matmul.matmul_bias_act(x, w)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------- perf bookkeeping

def test_vmem_footprint_within_tpu_budget():
    # Default tiles must fit a TPU core's VMEM (~16 MiB) with headroom.
    assert matmul.vmem_footprint_bytes() < 4 * 1024 * 1024


def test_mxu_utilization_perfect_when_aligned():
    assert matmul.mxu_utilization_estimate(256, 256, 256) == 1.0


def test_mxu_utilization_penalizes_ragged():
    u = matmul.mxu_utilization_estimate(129, 128, 128)
    assert 0.4 < u < 0.6  # 129/256 of issued M-rows useful


# ------------------------------------------------------- adaptive tiling

def test_auto_blocks_prefers_whole_k():
    bm, bn, bk = matmul.auto_blocks(784, 432, 48)
    assert bk >= 432, "single K panel expected for small K"
    assert bn == 48 or bn == 128
    assert matmul.vmem_footprint_bytes(bm, bn, bk) <= matmul.VMEM_BUDGET_BYTES


def test_auto_blocks_respects_vmem_budget():
    for m, k, n in [(1, 1, 1), (10_000, 8192, 4096), (900, 27, 8), (128, 4096, 128)]:
        bm, bn, bk = matmul.auto_blocks(m, k, n)
        assert matmul.vmem_footprint_bytes(bm, bn, bk) <= matmul.VMEM_BUDGET_BYTES, (m, k, n)
        assert bm >= 8 and bn >= 8 and bk >= 8


def test_auto_blocks_n_capped_at_mxu_width():
    _, bn, _ = matmul.auto_blocks(256, 256, 4096)
    assert bn == matmul.MAX_BLOCK_N == 128


def test_kernel_correct_with_auto_blocks_on_large_k():
    # Shapes that exercise the shrink-K fallback path.
    x = _rand(0, (64, 5000), jnp.float32)
    w = _rand(1, (5000, 32), jnp.float32)
    got = matmul.matmul_bias_act(x, w)
    want = ref.matmul_bias_act_ref(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=2e-2)


def test_mxu_utilization_reasonable_for_edgenet_shapes():
    # The §Perf claim: >= 0.78 useful-MAC fraction on EdgeNet GEMMs.
    for m, k, n in [(900, 27, 48), (784, 432, 48), (144, 432, 96), (100, 864, 96)]:
        bm, bn, bk = matmul.auto_blocks(m, k, n)
        u = matmul.mxu_utilization_estimate(m, n, k, bm, bn, bk)
        assert u >= 0.70, f"{(m, k, n)}: {u}"
