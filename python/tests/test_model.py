"""L2 correctness: EdgeNet forward (Pallas path) vs independent reference.

Also pins the tier ladder properties the scheduler relies on: parameter
count, FLOPs and profile accuracy must all be monotone in the tier order.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

TIER_ORDER = ["tiny", "small", "base", "large"]


def _images(seed, batch):
    return jax.random.uniform(
        jax.random.PRNGKey(seed), (batch, model.IMAGE_SIZE, model.IMAGE_SIZE, model.IMAGE_CHANNELS)
    )


@pytest.mark.parametrize("tier", TIER_ORDER)
@pytest.mark.parametrize("batch", [1, 3, 8])
def test_forward_matches_ref(tier, batch):
    params = model.init_params(tier)
    imgs = _images(7, batch)
    got = model.forward(params, imgs, tier)
    want = model.forward_ref(params, imgs, tier)
    assert got.shape == (batch, model.NUM_CLASSES)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-4, atol=3e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), batch=st.integers(1, 6))
def test_forward_matches_ref_hypothesis(seed, batch):
    params = model.init_params("small")
    imgs = _images(seed, batch)
    got = model.forward(params, imgs, "small")
    want = model.forward_ref(params, imgs, "small")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-4, atol=3e-4)


def test_im2col_matches_ref():
    imgs = _images(3, 2)
    got = model._im2col(imgs, 3, 3)
    want = ref.im2col_ref(imgs, 3, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


def test_im2col_strided_matches_ref():
    imgs = _images(4, 2)
    got = model._im2col(imgs, 3, 3, stride=2)
    want = ref.im2col_ref(imgs, 3, 3, stride=2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


def test_params_deterministic():
    a = model.init_params("tiny")
    b = model.init_params("tiny")
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))


def test_params_differ_across_seeds():
    a = model.init_params("tiny", seed=1)
    b = model.init_params("tiny", seed=2)
    assert any(not np.array_equal(np.asarray(a[k]), np.asarray(b[k])) for k in a)


def test_tier_ladder_monotone():
    counts = [model.param_count(t) for t in TIER_ORDER]
    flops = [model.flops_per_image(t) for t in TIER_ORDER]
    accs = [model.TIERS[t].profile_accuracy for t in TIER_ORDER]
    assert counts == sorted(counts) and len(set(counts)) == len(counts)
    assert flops == sorted(flops) and len(set(flops)) == len(flops)
    assert accs == sorted(accs) and len(set(accs)) == len(accs)


def test_forward_batch_consistency():
    """Row i of a batched forward equals the single-image forward."""
    params = model.init_params("tiny")
    imgs = _images(11, 4)
    batched = np.asarray(model.forward(params, imgs, "tiny"))
    for i in range(4):
        single = np.asarray(model.forward(params, imgs[i : i + 1], "tiny"))
        np.testing.assert_allclose(batched[i], single[0], rtol=1e-4, atol=1e-4)


def test_serving_fn_closes_over_params():
    fn, spec = model.serving_fn("tiny", batch=2)
    assert spec.shape == (2, 32, 32, 3)
    imgs = _images(5, 2)
    (got,) = fn(imgs)
    want = model.forward(model.init_params("tiny"), imgs, "tiny")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_logits_finite():
    for tier in TIER_ORDER:
        params = model.init_params(tier)
        out = np.asarray(model.forward(params, _images(9, 2), tier))
        assert np.all(np.isfinite(out))
