"""AOT path: HLO-text artifacts + manifest consumed by the rust runtime.

The critical invariants: (i) no elided constants (``{...}``) — elision would
silently corrupt the baked-in parameters; (ii) the lowered module's
entry layout matches the manifest; (iii) the HLO text round-trips through
the XLA client and reproduces the jax numerics.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def tiny_build(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.build(str(out), tiers=["tiny"], batches=[1, 2], verbose=False)
    return str(out), manifest


def test_manifest_contents(tiny_build):
    out, manifest = tiny_build
    assert manifest["format"] == "hlo-text"
    assert len(manifest["artifacts"]) == 2
    a = manifest["artifacts"][0]
    assert a["tier"] == "tiny"
    assert a["input_shape"] == [1, 32, 32, 3]
    assert a["output_shape"] == [1, 10]
    assert a["params"] == model.param_count("tiny")
    assert os.path.exists(os.path.join(out, a["file"]))
    assert os.path.exists(os.path.join(out, "manifest.json"))


def test_no_elided_constants(tiny_build):
    out, manifest = tiny_build
    for a in manifest["artifacts"]:
        text = open(os.path.join(out, a["file"])).read()
        assert "{...}" not in text, f"{a['name']} has elided constants"


def test_entry_layout_is_images_to_logit_tuple(tiny_build):
    out, manifest = tiny_build
    text = open(os.path.join(out, manifest["artifacts"][0]["file"])).read()
    header = text.splitlines()[0]
    assert "f32[1,32,32,3]" in header
    assert "(f32[1,10]" in header  # return_tuple=True => 1-tuple output


def test_hlo_text_round_trip_numerics(tiny_build):
    """Parse the emitted text back and execute it: must match jax."""
    from jax._src.lib import xla_client as xc

    out, manifest = tiny_build
    entry = manifest["artifacts"][1]  # batch=2
    text = open(os.path.join(out, entry["file"])).read()
    comp = xc._xla.XlaComputation(
        xc._xla.hlo_module_from_text(text).as_serialized_hlo_module_proto()
    )
    client = xc._xla.get_tfrt_cpu_client()
    mlir_module = xc._xla.mlir.xla_computation_to_mlir_module(comp)
    exe = client.compile_and_load(
        mlir_module, xc._xla.DeviceList(tuple(client.local_devices()[:1]))
    )
    imgs = np.asarray(
        jax.random.uniform(jax.random.PRNGKey(0), (2, 32, 32, 3)), dtype=np.float32
    )
    (bufs,) = exe.execute_sharded([client.buffer_from_pyval(imgs)]).disassemble_into_single_device_arrays()
    got = np.asarray(bufs[0])
    want = np.asarray(model.forward(model.init_params("tiny"), jnp.asarray(imgs), "tiny"))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_build_is_deterministic(tmp_path):
    m1 = aot.build(str(tmp_path / "a"), tiers=["tiny"], batches=[1], verbose=False)
    m2 = aot.build(str(tmp_path / "b"), tiers=["tiny"], batches=[1], verbose=False)
    assert m1["artifacts"][0]["sha256"] == m2["artifacts"][0]["sha256"]


def test_default_tier_set_covers_ladder():
    assert list(model.TIERS) == ["tiny", "small", "base", "large"]
