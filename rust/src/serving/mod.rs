//! The live serving runtime — the paper's Raspberry-Pi testbed rebuilt as
//! a concurrent rust system with *real* model execution (DESIGN.md
//! §Substitutions):
//!
//! * users submit image-classification requests to their covering edge
//!   server's bounded admission queue (paper: queue length 4);
//! * a leader runs the configured [`Scheduler`] every decision frame
//!   (paper: 3000 ms) or as soon as a queue fills;
//! * decisions dispatch to server nodes — local, peer edge, or cloud —
//!   over simulated wireless links whose realized bandwidth feeds the
//!   paper's `E[B_{t+1}] = (B_t + B_{t-1})/2` estimator;
//! * every served request runs real EdgeNet inference through PJRT on the
//!   node's engine thread, embedded in the node's calibrated
//!   processing-delay profile (edge ≈ 1300 ms, cloud ≈ 300 ms), or a mock
//!   engine when [`ServingConfig::synthetic`] is set (no artifacts
//!   needed);
//! * satisfaction is scored exactly as in Def. II.1 against the request's
//!   (A_i, C_i);
//! * an optional scenario [`Script`] replays live-world dynamics at frame
//!   boundaries — outages, mobility, bursts, bandwidth drift, placement
//!   churn — through the same [`ScenarioEngine`] the DES uses (DESIGN.md
//!   §Serving-Scenarios).
//!
//! Everything runs in scaled simulated time (see [`clock::SimClock`]) so
//! a two-hour-equivalent run takes seconds while preserving every ratio.

pub mod clock;
pub mod node;

use crate::coordinator::explain::{explain_schedule, Outcome};
use crate::coordinator::us::Assignment;
use crate::coordinator::{scheduler_by_name, SchedScratch, Schedule, Scheduler};
use crate::metrics::{PhaseMetrics, ServingMetrics};
use crate::model::request::Request;
use crate::model::server::{Server, ServerClass};
use crate::model::service::{Placement, ServiceCatalog, ServiceId, TierId, TierProfile};
use crate::model::topology::Topology;
use crate::model::{ProblemInstance, ServerId};
use crate::net::{BandwidthEstimator, Link};
use crate::obs::{DropReason, Recorder, PID_VIRTUAL, PID_WALL};
use crate::runtime::Manifest;
use crate::scenario::{EventKind, ScenarioEngine, Script};
use crate::serving::clock::SimClock;
use crate::serving::node::{Completion, ExecJob, InferenceHandle, ServerNode};
use crate::sim::{AdmissionQueue, FrameClock};
use crate::util::rng::Rng;
use crate::workload::pick_weighted;
use anyhow::{Context, Result};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::channel;
use std::sync::{Arc, Mutex};

/// Configuration of one serving run (paper testbed defaults).
#[derive(Clone, Debug)]
pub struct ServingConfig {
    pub artifacts_dir: String,
    /// Edge servers (paper testbed: 2 RP4s).
    pub num_edge: usize,
    /// Tiers placed on each edge (SqueezeNet-class models).
    pub edge_tiers: Vec<String>,
    /// Tiers placed on the cloud (empty = all manifest tiers).
    pub cloud_tiers: Vec<String>,
    /// Scheduling policy name (`gus`, `random`, `local-all`, ...).
    pub scheduler: String,
    /// Total requests to generate.
    pub total_requests: usize,
    /// Arrival window: requests arrive Poisson over this span (sim ms).
    pub window_ms: f64,
    /// Decision frame (paper: 3000 ms).
    pub frame_ms: f64,
    /// Admission queue capacity per edge (paper: 4).
    pub queue_capacity: usize,
    /// Executor workers per edge (paper: 3 threads).
    pub gamma_edge: usize,
    pub gamma_cloud: usize,
    /// Images forwardable per edge per frame (paper: 10).
    pub eta_edge: f64,
    pub eta_cloud: f64,
    /// QoS thresholds, fixed for all requests as in the paper.
    pub min_accuracy_pct: f64,
    pub deadline_ms: f64,
    /// Calibrated processing delays for the fastest tier (ms).
    pub edge_proc_base_ms: f64,
    pub cloud_proc_base_ms: f64,
    /// Per-tier-step processing slowdown.
    pub tier_slowdown: f64,
    /// Simulated ms per real ms (1.0 = real time).
    pub time_scale: f64,
    pub seed: u64,
    /// Scenario script replayed against the live world at frame
    /// boundaries (None = static world, the pre-scenario behavior).
    pub script: Option<Script>,
    /// Mock inference: serve canned logits through the real thread/channel
    /// topology instead of PJRT — runs without compiled artifacts.
    pub synthetic: bool,
}

impl Default for ServingConfig {
    fn default() -> Self {
        ServingConfig {
            artifacts_dir: "artifacts".into(),
            num_edge: 2,
            edge_tiers: vec!["tiny".into(), "small".into()],
            cloud_tiers: Vec::new(),
            scheduler: "gus".into(),
            total_requests: 120,
            window_ms: 60_000.0,
            frame_ms: 3_000.0,
            queue_capacity: 4,
            gamma_edge: 3,
            gamma_cloud: 8,
            eta_edge: 10.0,
            eta_cloud: 48.0,
            min_accuracy_pct: 50.0,
            deadline_ms: 5_300.0,
            edge_proc_base_ms: 1_300.0,
            cloud_proc_base_ms: 300.0,
            tier_slowdown: 1.10,
            time_scale: 50.0,
            seed: 7,
            script: None,
            synthetic: false,
        }
    }
}

/// A generated user request while it waits for a decision.
struct ServeRequest {
    id: u64,
    arrival_sim_ms: f64,
    payload_bytes: u64,
    images: Vec<f32>,
}

/// Per-frame world snapshot handed to a [`ServingSystem::with_probe`]
/// observer after the scenario advance and dispatch of each fired frame —
/// the hook the live-path property tests assert invariants on
/// (committed inflight ≤ γ, no dispatch to a down server).
#[derive(Clone, Debug)]
pub struct FrameProbe {
    pub now_ms: f64,
    /// Scripted events applied at this boundary.
    pub events_applied: u64,
    /// Per-server scenario liveness.
    pub up: Vec<bool>,
    /// Per-server committed inflight (executing + reserved in transfer),
    /// sampled after this frame's dispatches.
    pub inflight: Vec<usize>,
    /// Per-server steady-state γ.
    pub gamma: Vec<f64>,
    /// Target server of every assignment dispatched this frame.
    pub assigned_servers: Vec<usize>,
}

type ProbeFn = dyn Fn(&FrameProbe) + Send + Sync;

/// Outcome tags for the scenario-phase log (arrival time, tag).
const OUTCOME_DROPPED: u8 = 0;
const OUTCOME_SERVED: u8 = 1;
const OUTCOME_SATISFIED: u8 = 2;

/// Arrival-process state shared between the leader (writer, at frame
/// boundaries) and the generator thread (reader, per arrival): scenario
/// mobility re-weights the covering-edge draw and `LoadBurst` windows
/// scale the Poisson rate. Burst fields are f64 bit patterns in atomics
/// so the generator never takes a lock on the arrival hot path for them.
struct ArrivalShared {
    weights: Mutex<Vec<f64>>,
    burst_mult_bits: AtomicU64,
    burst_until_bits: AtomicU64,
}

/// Every site that accounts a dropped request funnels through this sink,
/// so metrics, the per-reason obs counters, the drop trace markers, the
/// phase log, and the run-termination counter can never drift apart.
struct DropSink {
    metrics: Arc<Mutex<ServingMetrics>>,
    finished: Arc<AtomicUsize>,
    recorder: Option<Arc<Recorder>>,
    /// `(arrival_ms, outcome tag)` log for phase segmentation; None for
    /// unscripted runs.
    outcomes: Option<Arc<Mutex<Vec<(f64, u8)>>>>,
}

impl DropSink {
    fn record(&self, reason: DropReason, track: u32, at_ms: f64, arrival_ms: f64, id: u64) {
        {
            let mut m = self.metrics.lock().unwrap();
            m.add_drop(reason);
        }
        if let Some(o) = &self.outcomes {
            o.lock().unwrap().push((arrival_ms, OUTCOME_DROPPED));
        }
        if let Some(r) = &self.recorder {
            r.add_labeled("edgeus_serve_dropped_total", "reason", reason.as_str(), 1.0);
            r.instant("serve", "drop", PID_VIRTUAL, track, at_ms, reason.as_str(), id);
        }
        self.finished.fetch_add(1, Ordering::SeqCst);
    }
}

/// Split the run's outcome log into scenario phases: one phase per
/// applied event (same-boundary events coalesce into one `a+b` phase),
/// plus the `start` prefix. Requests are assigned by arrival time, so
/// the phases partition the run exactly.
fn segment_phases(applied: &[(f64, &'static str)], outcomes: &[(f64, u8)]) -> Vec<PhaseMetrics> {
    let mut phases =
        vec![PhaseMetrics { label: "start".to_string(), from_ms: 0.0, ..Default::default() }];
    for (t, label) in applied {
        let same_boundary = phases.last().map(|p| p.from_ms == *t).unwrap_or(false);
        if same_boundary {
            if let Some(last) = phases.last_mut() {
                last.label.push('+');
                last.label.push_str(label);
            }
        } else {
            phases.push(PhaseMetrics {
                label: (*label).to_string(),
                from_ms: *t,
                ..Default::default()
            });
        }
    }
    for (arrival, kind) in outcomes {
        let idx = phases.iter().rposition(|p| p.from_ms <= *arrival).unwrap_or(0);
        let p = &mut phases[idx];
        p.requests += 1;
        match *kind {
            OUTCOME_DROPPED => p.dropped += 1,
            OUTCOME_SERVED => p.served += 1,
            _ => {
                p.served += 1;
                p.satisfied += 1;
            }
        }
    }
    phases
}

/// The assembled serving system.
pub struct ServingSystem {
    cfg: ServingConfig,
    manifest: Manifest,
    tiers: Vec<String>,
    recorder: Option<Arc<Recorder>>,
    probe: Option<Arc<ProbeFn>>,
}

impl ServingSystem {
    pub fn new(cfg: ServingConfig) -> Result<ServingSystem> {
        let manifest = if cfg.synthetic {
            Manifest::synthetic()
        } else {
            Manifest::load(&cfg.artifacts_dir)?
        };
        let tiers = manifest.tiers();
        for t in cfg.edge_tiers.iter().chain(cfg.cloud_tiers.iter()) {
            if !tiers.contains(t) {
                anyhow::bail!("tier {t} not in manifest (has {tiers:?})");
            }
        }
        if let Some(script) = &cfg.script {
            // Gate the script against *this* world's shape (the CLI runs
            // the same check with byte offsets; this covers library and
            // test callers). Horizon = arrival window + one deadline: the
            // tail where late arrivals can still observe an event.
            let shape = crate::verify::WorldShape {
                num_servers: cfg.num_edge + 1,
                num_edges: cfg.num_edge,
                num_services: 1,
                num_tiers: tiers.len(),
            };
            let d = crate::verify::verify_script(
                script,
                &shape,
                Some(cfg.window_ms + cfg.deadline_ms),
            );
            if d.has_errors() {
                anyhow::bail!(
                    "scenario script rejected for this serving world:\n{}",
                    d.render_text()
                );
            }
        }
        Ok(ServingSystem { cfg, manifest, tiers, recorder: None, probe: None })
    }

    /// Attach an observability recorder; a disabled one is free.
    pub fn with_recorder(mut self, recorder: Arc<Recorder>) -> ServingSystem {
        self.recorder = Some(recorder);
        self
    }

    /// Attach a per-frame probe, called after each fired frame's scenario
    /// advance + dispatch (test hook; see [`FrameProbe`]).
    pub fn with_probe(mut self, probe: Arc<ProbeFn>) -> ServingSystem {
        self.probe = Some(probe);
        self
    }

    /// The scheduler-visible catalog: one service ("classify") whose tiers
    /// are the real compiled artifacts, with paper-calibrated delays.
    fn catalog(&self) -> ServiceCatalog {
        let cfg = &self.cfg;
        let profiles: Vec<TierProfile> = self
            .tiers
            .iter()
            .enumerate()
            .map(|(i, tier)| {
                let acc = self
                    .manifest
                    .find(tier, 1)
                    .map(|a| a.profile_accuracy_pct)
                    .unwrap_or(50.0);
                let slow = cfg.tier_slowdown.powi(i as i32);
                let mut proc = [0.0; ServerClass::COUNT];
                for (ci, speed) in [1.15, 1.0, 0.85].iter().enumerate() {
                    proc[ci] = cfg.edge_proc_base_ms * slow * speed;
                }
                proc[ServerClass::Cloud.index()] = cfg.cloud_proc_base_ms * slow;
                TierProfile {
                    accuracy_pct: acc,
                    proc_ms: proc,
                    comp_cost: 1.0,
                    comm_cost: 1.0,
                    model_bytes: 0,
                }
            })
            .collect();
        ServiceCatalog::from_profiles(vec![profiles])
    }

    fn placement(&self) -> Placement {
        let cfg = &self.cfg;
        let tier_idx = |name: &str| TierId(self.tiers.iter().position(|t| t == name).unwrap()); // lint:allow(unwrap) — self.tiers is built from these same names
        let mut on = Vec::new();
        let mut cloud_flags = Vec::new();
        for _ in 0..cfg.num_edge {
            let mut pairs: Vec<(ServiceId, TierId)> =
                cfg.edge_tiers.iter().map(|t| (ServiceId(0), tier_idx(t))).collect();
            pairs.sort();
            on.push(pairs);
            cloud_flags.push(false);
        }
        // Cloud: explicit tier list, or everything.
        if cfg.cloud_tiers.is_empty() {
            on.push(Vec::new());
            cloud_flags.push(true);
        } else {
            let mut pairs: Vec<(ServiceId, TierId)> =
                cfg.cloud_tiers.iter().map(|t| (ServiceId(0), tier_idx(t))).collect();
            pairs.sort();
            on.push(pairs);
            cloud_flags.push(false);
        }
        Placement::explicit(on, cloud_flags)
    }

    fn cloud_tier_names(&self) -> Vec<String> {
        if self.cfg.cloud_tiers.is_empty() {
            self.tiers.clone()
        } else {
            self.cfg.cloud_tiers.clone()
        }
    }

    /// Run to completion; returns the end-to-end metrics.
    pub fn run(&self) -> Result<ServingMetrics> {
        let cfg = &self.cfg;
        let scheduler: Box<dyn Scheduler + Send + Sync> = scheduler_by_name(&cfg.scheduler)
            .with_context(|| format!("unknown scheduler {}", cfg.scheduler))?;
        let clock = SimClock::new(cfg.time_scale);
        let catalog = self.catalog();
        let mut placement = self.placement();
        let cloud_id = cfg.num_edge; // last server
        let num_servers = cfg.num_edge + 1;

        // Observability: Some only for an enabled recorder, so the
        // request path pays one branch per site when off.
        let recorder = self.recorder.clone().filter(|r| r.is_enabled());
        if let Some(r) = &recorder {
            for reason in DropReason::ALL {
                r.declare("edgeus_serve_dropped_total", "reason", reason.as_str());
            }
        }
        let wall_t0 = std::time::Instant::now();

        // Network links + bandwidth estimator (edge↔cloud path).
        let edge_cloud_link = Link::edge_cloud_default();
        let edge_edge_link = Link::edge_edge_default();
        let mut estimator = BandwidthEstimator::new(600.0);

        // The persistent live world. Unlike the pre-scenario runtime —
        // which rebuilt a throwaway `Topology` (fresh `Vec<Vec<f64>>` comm
        // matrix and all) every frame — the topology lives across the
        // whole run: γ/η hold the steady-state capacities (per-frame
        // residuals ride the instance's side slice), the comm matrix is
        // the flattened row-major `Topology::comm_ms` buffer updated in
        // place, and scenario events mutate servers/links/placement
        // through the generation-bumping mutators so the GUS rank cache
        // invalidates exactly the touched classes.
        let mean_payload = 14_000u64;
        let cloud_ms0 =
            estimator.expected_delay_ms(mean_payload) + edge_cloud_link.propagation_ms;
        let edge_ms0 = edge_edge_link.expected_delay_ms(mean_payload);
        let mut servers = Vec::with_capacity(num_servers);
        let mut comm0 = vec![vec![0.0; num_servers]; num_servers];
        for j in 0..num_servers {
            let class =
                if j == cloud_id { ServerClass::Cloud } else { ServerClass::EDGE_CLASSES[j % 3] };
            let gamma = if j == cloud_id { cfg.gamma_cloud } else { cfg.gamma_edge } as f64;
            let eta = if j == cloud_id { cfg.eta_cloud } else { cfg.eta_edge };
            servers.push(Server::new(j, class).with_capacities(gamma, eta));
            for b in 0..num_servers {
                if j != b {
                    comm0[j][b] = if j == cloud_id || b == cloud_id { cloud_ms0 } else { edge_ms0 };
                }
            }
        }
        let mut topology = Topology::explicit(servers, comm0);
        let mut engine = cfg
            .script
            .as_ref()
            .map(|s| ScenarioEngine::new(s.clone(), &topology, 1, self.tiers.len()));
        let scripted = engine.is_some();

        // Metrics plumbing.
        let metrics = Arc::new(Mutex::new(ServingMetrics::default()));
        let finished = Arc::new(AtomicUsize::new(0));
        let outcomes: Option<Arc<Mutex<Vec<(f64, u8)>>>> = if scripted {
            Some(Arc::new(Mutex::new(Vec::with_capacity(cfg.total_requests))))
        } else {
            None
        };
        let sink = Arc::new(DropSink {
            metrics: Arc::clone(&metrics),
            finished: Arc::clone(&finished),
            recorder: recorder.clone(),
            outcomes: outcomes.clone(),
        });
        let (completion_tx, completion_rx) = channel::<(Completion, f64, f64)>();

        // Collector thread: scores Def. II.1 satisfaction per completion.
        let collector = {
            let metrics = Arc::clone(&metrics);
            let finished = Arc::clone(&finished);
            let recorder = recorder.clone();
            let outcomes = outcomes.clone();
            std::thread::spawn(move || {
                while let Ok((c, a_min, c_max)) = completion_rx.recv() {
                    let ok = c.accuracy_pct >= a_min && c.completion_ms <= c_max;
                    let mut m = metrics.lock().unwrap();
                    m.served += 1;
                    if ok {
                        m.satisfied += 1;
                    }
                    let kind = if c.served_local {
                        m.local += 1;
                        "local"
                    } else if c.served_by_cloud {
                        m.offload_cloud += 1;
                        "cloud"
                    } else {
                        m.offload_peer += 1;
                        "peer"
                    };
                    m.latency.record(c.completion_ms);
                    m.inference.record(c.inference_real_ms.max(1e-3));
                    drop(m);
                    if let Some(o) = &outcomes {
                        o.lock().unwrap().push((
                            c.arrival_sim_ms,
                            if ok { OUTCOME_SATISFIED } else { OUTCOME_SERVED },
                        ));
                    }
                    if let Some(r) = &recorder {
                        // Full lifecycle span: arrival → reply, in sim time.
                        let track = match kind {
                            "local" => 0,
                            "cloud" => 1,
                            _ => 2,
                        };
                        r.span(
                            "serve",
                            "serve",
                            PID_VIRTUAL,
                            track,
                            c.arrival_sim_ms,
                            c.completion_ms,
                            c.request_id,
                        );
                        r.add("edgeus_serve_served_total", 1.0);
                        if ok {
                            r.add("edgeus_serve_satisfied_total", 1.0);
                        }
                        r.add_labeled("edgeus_serve_assigned_total", "kind", kind, 1.0);
                        r.add("edgeus_serve_inference_ms_total", c.inference_real_ms.max(0.0));
                    }
                    finished.fetch_add(1, Ordering::SeqCst);
                }
            })
        };

        // Wrap node completions with the fixed QoS thresholds.
        let (node_tx, node_rx) = channel::<Completion>();
        let qos_fwd = {
            let completion_tx = completion_tx.clone();
            let a_min = cfg.min_accuracy_pct;
            let c_max = cfg.deadline_ms;
            std::thread::spawn(move || {
                while let Ok(c) = node_rx.recv() {
                    let _ = completion_tx.send((c, a_min, c_max));
                }
            })
        };

        // Spawn server nodes (edges cycle through classes, like the sim).
        // Scripts with placement churn make every node load the full tier
        // ladder, so a tier placed mid-run can actually execute.
        let script_has_placement = cfg
            .script
            .as_ref()
            .map(|s| s.events.iter().any(|e| matches!(e.kind, EventKind::PlacementChange { .. })))
            .unwrap_or(false);
        let spawn_node = |id: usize, class: ServerClass, tiers: Vec<String>, gamma: usize| {
            let engine = if cfg.synthetic {
                InferenceHandle::spawn_synthetic(self.manifest.num_classes, gamma.min(4))?
            } else {
                InferenceHandle::spawn_pool(&cfg.artifacts_dir, tiers.clone(), gamma.min(4))?
            };
            ServerNode::spawn_with_engine(id, class, tiers, engine, gamma, clock, node_tx.clone())
        };
        let mut nodes: Vec<Arc<ServerNode>> = Vec::new();
        for e in 0..cfg.num_edge {
            let tiers =
                if script_has_placement { self.tiers.clone() } else { cfg.edge_tiers.clone() };
            nodes.push(Arc::new(spawn_node(
                e,
                ServerClass::EDGE_CLASSES[e % 3],
                tiers,
                cfg.gamma_edge,
            )?));
        }
        nodes.push(Arc::new(spawn_node(
            cloud_id,
            ServerClass::Cloud,
            self.cloud_tier_names(),
            cfg.gamma_cloud,
        )?));
        drop(node_tx);

        // Admission queues.
        let queues: Vec<Arc<Mutex<AdmissionQueue<ServeRequest>>>> = (0..cfg.num_edge)
            .map(|_| Arc::new(Mutex::new(AdmissionQueue::new(cfg.queue_capacity))))
            .collect();

        // Arrival-process state the scenario engine steers (weights start
        // uniform, no burst).
        let arrivals = Arc::new(ArrivalShared {
            weights: Mutex::new(vec![1.0; cfg.num_edge]),
            burst_mult_bits: AtomicU64::new(1.0f64.to_bits()),
            burst_until_bits: AtomicU64::new(f64::NEG_INFINITY.to_bits()),
        });

        // Request generator.
        let generated = Arc::new(AtomicU64::new(0));
        let image_len = self.manifest.image_size * self.manifest.image_size
            * self.manifest.image_channels;
        let generator = {
            let queues: Vec<_> = queues.iter().map(Arc::clone).collect();
            let generated = Arc::clone(&generated);
            let recorder = recorder.clone();
            let sink = Arc::clone(&sink);
            let arrivals = Arc::clone(&arrivals);
            let total = cfg.total_requests;
            let window = cfg.window_ms;
            let seed = cfg.seed;
            std::thread::spawn(move || {
                let mut rng = Rng::new(seed);
                let mean_gap = window / total.max(1) as f64;
                for id in 0..total as u64 {
                    // Poisson arrivals: exponential inter-arrival gaps.
                    // Scripted runs scale the rate by the live burst
                    // window and draw the covering edge from the
                    // scenario's mobility/outage-masked weights; plain
                    // runs keep the legacy uniform draw stream.
                    let gap = if scripted {
                        let until =
                            f64::from_bits(arrivals.burst_until_bits.load(Ordering::SeqCst));
                        let mult = if clock.now_ms() < until {
                            f64::from_bits(arrivals.burst_mult_bits.load(Ordering::SeqCst))
                        } else {
                            1.0
                        };
                        -(mean_gap / mult) * (1.0 - rng.f64()).ln()
                    } else {
                        -mean_gap * (1.0 - rng.f64()).ln()
                    };
                    clock.sleep_ms(gap.min(mean_gap * 10.0));
                    let edge = if scripted {
                        let w = arrivals.weights.lock().unwrap();
                        pick_weighted(&w, &mut rng)
                    } else {
                        rng.index(queues.len())
                    };
                    let images: Vec<f32> = (0..image_len).map(|_| rng.f64() as f32).collect();
                    let req = ServeRequest {
                        id,
                        arrival_sim_ms: clock.now_ms(),
                        payload_bytes: rng.u64_range(8_000, 20_000),
                        images,
                    };
                    let arrival_sim = req.arrival_sim_ms;
                    generated.fetch_add(1, Ordering::SeqCst);
                    let admitted = queues[edge].lock().unwrap().push(req, clock.now_ms());
                    if let Some(r) = &recorder {
                        r.instant("serve", "arrival", PID_VIRTUAL, edge as u32, arrival_sim, "", id);
                        r.add("edgeus_serve_arrivals_total", 1.0);
                    }
                    if !admitted {
                        // Bounded admission queue rejection: the only drop
                        // site outside the scheduler's decision and the
                        // mid-transfer outage fallback.
                        sink.record(DropReason::QueueFull, edge as u32, arrival_sim, arrival_sim, id);
                    }
                }
            })
        };

        // Leader loop: decision frames. Scheduler working memory and the
        // schedule output live outside the loop so steady-state frames
        // reuse warm buffers (and the GUS rank cache) instead of
        // reallocating per decision.
        let mut frame = FrameClock::new(cfg.frame_ms);
        let mut leader_rng = Rng::new(cfg.seed ^ 0xD15BA7C4);
        let mut sched_scratch = SchedScratch::default();
        let mut schedule = Schedule::empty(0);
        let mut residual = vec![0.0f64; num_servers];
        let mut last_backhaul_drift = 1.0f64;
        let mut peer_drift = 1.0f64;
        let real_tick = std::time::Duration::from_secs_f64(
            (cfg.frame_ms / cfg.time_scale / 1e3 / 20.0).max(0.0005),
        );
        let mut dispatch_threads: Vec<std::thread::JoinHandle<()>> = Vec::new();
        loop {
            let done = finished.load(Ordering::SeqCst) >= cfg.total_requests;
            if done {
                break;
            }
            std::thread::sleep(real_tick);
            let now = clock.now_ms();
            let any_full = queues.iter().any(|q| q.lock().unwrap().is_full());
            let any_waiting = queues.iter().any(|q| !q.lock().unwrap().is_empty());
            if !frame.should_fire(now, any_full) {
                continue;
            }
            // Scripted runs fire every boundary (events apply on time even
            // through lulls — the DES cadence); plain runs keep the lazy
            // legacy cadence and only fire with work waiting.
            if engine.is_none() && !any_waiting {
                continue;
            }
            frame.fired(now);

            // Scenario advance: same application point as the DES decide
            // loop — events land at the frame boundary, before this
            // frame's world snapshot is taken.
            let mut events_applied = 0u64;
            if let Some(eng) = engine.as_mut() {
                events_applied =
                    eng.advance_traced(now, &mut topology, &mut placement, recorder.as_deref());
                if events_applied > 0 {
                    // Outages → node dispatch gates (mid-transfer work
                    // redirects; executing jobs drain to completion).
                    for (j, node) in nodes.iter().enumerate() {
                        node.set_up(topology.servers[j].up);
                    }
                    // Mobility / outage masking → generator edge weights;
                    // bursts → generator rate window.
                    {
                        let mut w = arrivals.weights.lock().unwrap();
                        eng.edge_weights_into(&topology, &mut w);
                    }
                    let (mult, until) = eng.burst_window();
                    arrivals.burst_mult_bits.store(mult.to_bits(), Ordering::SeqCst);
                    arrivals.burst_until_bits.store(until.to_bits(), Ordering::SeqCst);
                    // Backhaul drift biases the paper's bandwidth
                    // estimator: both of its samples jump to the drifted
                    // channel, exactly as the DES's comm matrix jumps.
                    let drift = eng.backhaul_drift();
                    if drift != last_backhaul_drift {
                        let biased = edge_cloud_link.mean_bytes_per_ms / drift;
                        estimator.observe(biased);
                        estimator.observe(biased);
                        last_backhaul_drift = drift;
                    }
                    peer_drift = eng.peer_drift();
                    if let Some(r) = &recorder {
                        r.sample(
                            "edgeus_serve_live_servers",
                            PID_VIRTUAL,
                            0,
                            now,
                            topology.servers.iter().filter(|s| s.up).count() as f64,
                        );
                    }
                }
            }

            // Drain all queues into one joint decision problem.
            let mut pending: Vec<(usize, ServeRequest, f64)> = Vec::new();
            for (e, q) in queues.iter().enumerate() {
                for (req, tq) in q.lock().unwrap().drain(now) {
                    pending.push((e, req, tq));
                }
            }
            if pending.is_empty() {
                if let Some(probe) = &self.probe {
                    probe(&FrameProbe {
                        now_ms: now,
                        events_applied,
                        up: topology.servers.iter().map(|s| s.up).collect(),
                        inflight: nodes.iter().map(|n| n.inflight()).collect(),
                        gamma: topology.servers.iter().map(|s| s.gamma).collect(),
                        assigned_servers: Vec::new(),
                    });
                }
                continue;
            }

            // lint:no-alloc:begin — steady-state world refresh. The comm
            // matrix is the persistent topology's flattened row-major
            // buffer written in place (guarded, so unchanged rows don't
            // invalidate rank-cache classes), and the residual-γ slice is
            // a pooled buffer — no per-frame Vec<Vec<f64>> rebuilds.
            let cloud_ms =
                estimator.expected_delay_ms(mean_payload) + edge_cloud_link.propagation_ms;
            let edge_ms = edge_edge_link.expected_delay_ms(mean_payload) * peer_drift;
            for a in 0..num_servers {
                for b in 0..num_servers {
                    if a == b {
                        continue;
                    }
                    let want = if a == cloud_id || b == cloud_id { cloud_ms } else { edge_ms };
                    if topology.comm_ms(ServerId(a), ServerId(b)) != want {
                        topology.set_comm_ms(ServerId(a), ServerId(b), want);
                    }
                }
            }
            for (j, node) in nodes.iter().enumerate() {
                residual[j] = (topology.servers[j].gamma - node.inflight() as f64).max(0.0);
            }
            // lint:no-alloc:end

            let requests: Vec<Request> = pending
                .iter()
                .enumerate()
                .map(|(i, (e, req, tq))| {
                    Request::new(i, 0, *e)
                        .with_qos(cfg.min_accuracy_pct, cfg.deadline_ms)
                        .with_queue_delay(*tq)
                        .with_payload(req.payload_bytes)
                })
                .collect();
            // Borrow the persistent world; the per-frame residual γ rides
            // the side slice (same shape as the DES hot path).
            let inst = ProblemInstance::borrowed(&topology, &catalog, &placement, requests)
                .with_normalization(100.0, 12_000.0)
                .with_residual_gamma(std::mem::take(&mut residual));
            let sched_w0 =
                recorder.as_ref().map(|_| wall_t0.elapsed().as_secs_f64() * 1e3);
            scheduler.schedule_into(&inst, &mut leader_rng, &mut sched_scratch, &mut schedule);
            if let (Some(r), Some(w0)) = (&recorder, sched_w0) {
                let w1 = wall_t0.elapsed().as_secs_f64() * 1e3;
                r.span("leader", "frame.schedule", PID_WALL, 0, w0, w1 - w0, 0);
                r.instant("leader", "decision", PID_VIRTUAL, 0, now, "", 0);
                r.sample("edgeus_serve_frame_requests", PID_VIRTUAL, 0, now, inst.requests.len() as f64);
            }
            // Post-hoc decision explanation: needed for the trace and to
            // classify scheduler-rejected requests by drop reason (a
            // request whose only viable targets are down counts as a
            // server-down drop, not a policy choice).
            let needs_explain =
                recorder.is_some() || schedule.slots.iter().any(|s| s.is_none());
            let explain = if needs_explain { Some(explain_schedule(&inst, &schedule)) } else { None };
            if let (Some(r), Some(ex)) = (&recorder, &explain) {
                r.add("edgeus_serve_candidates_total", ex.candidates_considered as f64);
            }
            // Hand the pooled residual buffer back for the next frame.
            let (_reqs, res) = inst.into_buffers();
            residual = res.unwrap_or_default();
            residual.resize(num_servers, 0.0);

            // Dispatch.
            for (i, (e, req, _tq)) in pending.into_iter().enumerate() {
                match &schedule.slots[i] {
                    None => {
                        let reason = explain
                            .as_ref()
                            .map(|ex| match ex.outcomes[i].outcome {
                                Outcome::Dropped(r) => r,
                                _ => DropReason::Policy,
                            })
                            .unwrap_or(DropReason::Policy);
                        sink.record(reason, e as u32, now, req.arrival_sim_ms, req.id);
                    }
                    Some(a) => {
                        self.dispatch(
                            a,
                            req,
                            e,
                            &nodes,
                            cloud_id,
                            clock,
                            &edge_cloud_link,
                            &edge_edge_link,
                            (last_backhaul_drift, peer_drift),
                            &mut estimator,
                            &mut leader_rng,
                            &sink,
                            &mut dispatch_threads,
                        );
                    }
                }
            }
            if let Some(probe) = &self.probe {
                probe(&FrameProbe {
                    now_ms: now,
                    events_applied,
                    up: topology.servers.iter().map(|s| s.up).collect(),
                    inflight: nodes.iter().map(|n| n.inflight()).collect(),
                    gamma: topology.servers.iter().map(|s| s.gamma).collect(),
                    assigned_servers: schedule
                        .slots
                        .iter()
                        .flatten()
                        .map(|a| a.candidate.server.0)
                        .collect(),
                });
            }
            // Reap finished transfer threads opportunistically.
            dispatch_threads.retain(|h| !h.is_finished());
        }

        generator.join().expect("generator panicked"); // lint:allow(unwrap) — propagate worker panics
        for h in dispatch_threads {
            let _ = h.join();
        }
        // Shut down nodes (drops engine threads), then the collector.
        for node in nodes {
            match Arc::try_unwrap(node) {
                Ok(n) => n.shutdown(),
                Err(_) => {} // a transfer thread still holds it; it exits on its own
            }
        }
        let _ = qos_fwd.join();
        drop(completion_tx);
        let _ = collector.join();

        let mut m = Arc::try_unwrap(metrics)
            .map(|m| m.into_inner().unwrap())
            .unwrap_or_else(|arc| arc.lock().unwrap().clone());
        m.total_requests = cfg.total_requests as u64;
        m.wall_ms = clock.now_ms();
        if let Some(eng) = &engine {
            let log = outcomes
                .as_ref()
                .map(|o| o.lock().unwrap().clone())
                .unwrap_or_default();
            m.phases = segment_phases(eng.applied_events(), &log);
        }
        // Every generated request must be accounted for exactly once —
        // overall and within every scenario phase.
        m.check_conservation().map_err(anyhow::Error::msg)?;
        Ok(m)
    }

    #[allow(clippy::too_many_arguments)]
    fn dispatch(
        &self,
        a: &Assignment,
        req: ServeRequest,
        covering_edge: usize,
        nodes: &[Arc<ServerNode>],
        cloud_id: usize,
        clock: SimClock,
        edge_cloud_link: &Link,
        edge_edge_link: &Link,
        (backhaul_drift, peer_drift): (f64, f64),
        estimator: &mut BandwidthEstimator,
        rng: &mut Rng,
        sink: &Arc<DropSink>,
        transfers: &mut Vec<std::thread::JoinHandle<()>>,
    ) {
        let tier_name = self.tiers[a.candidate.tier.0].clone();
        let target = Arc::clone(&nodes[a.candidate.server.0]);
        let slow = self.cfg.tier_slowdown.powi(a.candidate.tier.0 as i32);
        let profile_proc = if target.class.is_cloud() {
            self.cfg.cloud_proc_base_ms * slow
        } else {
            // Same calibration as `catalog()`.
            let speed = [1.15, 1.0, 0.85][target.class.index()];
            self.cfg.edge_proc_base_ms * slow * speed
        };
        let payload = req.payload_bytes;
        let job = ExecJob {
            request_id: req.id,
            arrival_sim_ms: req.arrival_sim_ms,
            tier: tier_name,
            proc_ms: profile_proc,
            accuracy_pct: a.candidate.accuracy_pct,
            images: req.images,
            served_local: !a.candidate.offloaded,
        };
        if !a.candidate.offloaded {
            // Local execution: the leader applied scenario events on this
            // same thread, so an up target cannot flip before submit.
            target.submit(job);
            return;
        }
        // Offload: sample the real link (scaled by any scenario drift),
        // feed the estimator the *observed* drifted channel, and forward
        // after the transfer delay.
        let to_cloud = a.candidate.server.0 == cloud_id;
        let (link, drift) =
            if to_cloud { (edge_cloud_link, backhaul_drift) } else { (edge_edge_link, peer_drift) };
        let (raw_delay, raw_bw) = link.transfer(payload, rng);
        let delay_ms = (raw_delay - link.propagation_ms) * drift + link.propagation_ms;
        if to_cloud {
            estimator.observe(raw_bw / backhaul_drift);
        }
        // The inflight slot is reserved *now*, so the next frame's
        // residual γ already counts work still crossing the link. If the
        // target dies mid-transfer, the covering edge re-forwards to the
        // cloud when it is live with a free slot; otherwise the request
        // is a server-down casualty.
        //
        // Edges only ever take commitments from this (leader) thread, so a
        // plain reservation stays within the residual-γ the scheduler saw.
        // The cloud also absorbs concurrent mid-transfer redirects: bound
        // its reservation by γ so committed inflight can never overshoot
        // even when a redirect lands between the residual snapshot and
        // this dispatch.
        let gamma_cloud = self.cfg.gamma_cloud;
        let track = covering_edge as u32;
        if to_cloud {
            if !target.try_reserve(gamma_cloud) {
                sink.record(
                    DropReason::CapacityExhausted,
                    track,
                    clock.now_ms(),
                    job.arrival_sim_ms,
                    job.request_id,
                );
                return;
            }
        } else {
            target.reserve();
        }
        if let Some(r) = &sink.recorder {
            r.span(
                "serve",
                "transfer",
                PID_VIRTUAL,
                a.candidate.server.0 as u32,
                clock.now_ms(),
                delay_ms,
                job.request_id,
            );
            r.add("edgeus_serve_transfers_total", 1.0);
        }
        let cloud = Arc::clone(&nodes[cloud_id]);
        let redirect_proc_ms = self.cfg.cloud_proc_base_ms * slow;
        let redirect_delay_ms = (edge_cloud_link.expected_delay_ms(payload)
            - edge_cloud_link.propagation_ms)
            * backhaul_drift
            + edge_cloud_link.propagation_ms;
        let sink = Arc::clone(sink);
        transfers.push(std::thread::spawn(move || {
            clock.sleep_ms(delay_ms);
            if target.is_up() {
                target.submit_reserved(job);
                return;
            }
            target.release();
            let mut job = job;
            if !to_cloud && cloud.is_up() && cloud.try_reserve(gamma_cloud) {
                job.proc_ms = redirect_proc_ms;
                job.served_local = false;
                if let Some(r) = &sink.recorder {
                    r.add("edgeus_serve_redirects_total", 1.0);
                    r.instant(
                        "serve",
                        "redirect",
                        PID_VIRTUAL,
                        track,
                        clock.now_ms(),
                        "",
                        job.request_id,
                    );
                }
                clock.sleep_ms(redirect_delay_ms);
                cloud.submit_reserved(job);
            } else {
                sink.record(
                    DropReason::ServerDown,
                    track,
                    clock.now_ms(),
                    job.arrival_sim_ms,
                    job.request_id,
                );
            }
        }));
    }
}

/// Fig. 1(e)–(h): sweep the offered load for each policy on the live
/// system, reporting satisfied / local / cloud / peer percentages.
pub struct TestbedExperiment {
    pub base: ServingConfig,
    pub policies: Vec<String>,
    pub loads: Vec<usize>,
    /// Optional recorder, attached to the first run of the sweep (tracing
    /// every run would interleave unrelated sweeps in one trace).
    pub recorder: Option<Arc<Recorder>>,
}

impl Default for TestbedExperiment {
    fn default() -> Self {
        TestbedExperiment {
            base: ServingConfig::default(),
            policies: vec![
                "gus".into(),
                "random".into(),
                "local-all".into(),
                "offload-all".into(),
            ],
            loads: vec![60, 120, 240, 360],
            recorder: None,
        }
    }
}

/// Result of the testbed sweep: one series per panel (e)–(h).
pub struct TestbedResult {
    pub satisfied: crate::metrics::Series,
    pub local: crate::metrics::Series,
    pub cloud: crate::metrics::Series,
    pub peer: crate::metrics::Series,
    /// Raw metrics per (policy, load).
    pub raw: Vec<(String, usize, ServingMetrics)>,
}

impl TestbedExperiment {
    pub fn run(&self) -> Result<TestbedResult> {
        let xs: Vec<f64> = self.loads.iter().map(|l| *l as f64).collect();
        let mut satisfied = crate::metrics::Series::new("requests", "satisfied users (%)", xs.clone());
        let mut local = crate::metrics::Series::new("requests", "locally processed (%)", xs.clone());
        let mut cloud = crate::metrics::Series::new("requests", "offloaded to cloud (%)", xs.clone());
        let mut peer = crate::metrics::Series::new("requests", "offloaded to peers (%)", xs);
        let nan = vec![f64::NAN; self.loads.len()];
        let mut raw = Vec::new();
        let mut recorder = self.recorder.clone();
        for policy in &self.policies {
            let mut s = Vec::new();
            let mut l = Vec::new();
            let mut c = Vec::new();
            let mut p = Vec::new();
            for &load in &self.loads {
                let mut cfg = self.base.clone();
                cfg.scheduler = policy.clone();
                cfg.total_requests = load;
                let mut system = ServingSystem::new(cfg)?;
                if let Some(r) = recorder.take() {
                    system = system.with_recorder(r);
                }
                let metrics = system.run()?;
                s.push(metrics.satisfied_pct());
                l.push(metrics.local_pct());
                c.push(metrics.cloud_pct());
                p.push(metrics.peer_pct());
                raw.push((policy.clone(), load, metrics));
            }
            satisfied.push_policy(policy, s, nan.clone());
            local.push_policy(policy, l, nan.clone());
            cloud.push_policy(policy, c, nan.clone());
            peer.push_policy(policy, p, nan.clone());
        }
        Ok(TestbedResult { satisfied, local, cloud, peer, raw })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_is_paper_calibrated() {
        let c = ServingConfig::default();
        assert_eq!(c.num_edge, 2);
        assert_eq!(c.queue_capacity, 4);
        assert_eq!(c.frame_ms, 3000.0);
        assert_eq!(c.gamma_edge, 3);
        assert_eq!(c.eta_edge, 10.0);
        assert_eq!(c.min_accuracy_pct, 50.0);
        assert_eq!(c.edge_proc_base_ms, 1300.0);
        assert_eq!(c.cloud_proc_base_ms, 300.0);
        assert!(c.script.is_none());
        assert!(!c.synthetic);
    }

    #[test]
    fn synthetic_system_builds_without_artifacts() {
        let cfg = ServingConfig { synthetic: true, ..ServingConfig::default() };
        let sys = ServingSystem::new(cfg).unwrap();
        assert_eq!(sys.tiers, vec!["tiny", "small", "base"]);
    }

    #[test]
    fn out_of_shape_script_is_rejected_at_build() {
        // Server 5 exists in the paper world but not in a 2-edge serving
        // config (3 servers): building the system must fail loudly.
        let script = Script::new(
            "oob",
            vec![crate::scenario::ScriptedEvent {
                at_ms: 1000.0,
                kind: EventKind::ServerDown { server: 5 },
            }],
        );
        let cfg =
            ServingConfig { synthetic: true, script: Some(script), ..ServingConfig::default() };
        let err = ServingSystem::new(cfg).unwrap_err().to_string();
        assert!(err.contains("E001"), "{err}");
    }

    #[test]
    fn phase_segmentation_partitions_and_coalesces() {
        let applied = [(9000.0, "server_down"), (9000.0, "load_burst"), (30_000.0, "server_up")];
        let outcomes = [
            (100.0, OUTCOME_SATISFIED),
            (8999.0, OUTCOME_DROPPED),
            (9000.0, OUTCOME_SERVED),
            (29_000.0, OUTCOME_SATISFIED),
            (31_000.0, OUTCOME_DROPPED),
        ];
        let phases = segment_phases(&applied, &outcomes);
        let labels: Vec<&str> = phases.iter().map(|p| p.label.as_str()).collect();
        assert_eq!(labels, vec!["start", "server_down+load_burst", "server_up"]);
        assert_eq!(phases[0].requests, 2);
        assert_eq!(phases[0].satisfied, 1);
        assert_eq!(phases[0].dropped, 1);
        assert_eq!(phases[1].requests, 2);
        assert_eq!(phases[1].served, 2);
        assert_eq!(phases[1].satisfied, 1);
        assert_eq!(phases[2].requests, 1);
        assert_eq!(phases[2].dropped, 1);
        let req: u64 = phases.iter().map(|p| p.requests).sum();
        assert_eq!(req, outcomes.len() as u64);
    }

    // Full-system tests live in rust/tests/serving_e2e.rs (artifacts
    // path) and rust/tests/serve_scenario_parity.rs (synthetic path).
}
