//! The live serving runtime — the paper's Raspberry-Pi testbed rebuilt as
//! a concurrent rust system with *real* model execution (DESIGN.md
//! §Substitutions):
//!
//! * users submit image-classification requests to their covering edge
//!   server's bounded admission queue (paper: queue length 4);
//! * a leader runs the configured [`Scheduler`] every decision frame
//!   (paper: 3000 ms) or as soon as a queue fills;
//! * decisions dispatch to server nodes — local, peer edge, or cloud —
//!   over simulated wireless links whose realized bandwidth feeds the
//!   paper's `E[B_{t+1}] = (B_t + B_{t-1})/2` estimator;
//! * every served request runs real EdgeNet inference through PJRT on the
//!   node's engine thread, embedded in the node's calibrated
//!   processing-delay profile (edge ≈ 1300 ms, cloud ≈ 300 ms);
//! * satisfaction is scored exactly as in Def. II.1 against the request's
//!   (A_i, C_i).
//!
//! Everything runs in scaled simulated time (see [`clock::SimClock`]) so
//! a two-hour-equivalent run takes seconds while preserving every ratio.

pub mod clock;
pub mod node;

use crate::coordinator::explain::{explain_schedule, Outcome};
use crate::coordinator::us::Assignment;
use crate::coordinator::{scheduler_by_name, SchedScratch, Schedule, Scheduler};
use crate::metrics::ServingMetrics;
use crate::model::request::Request;
use crate::model::server::{Server, ServerClass};
use crate::model::service::{Placement, ServiceCatalog, ServiceId, TierId, TierProfile};
use crate::model::topology::Topology;
use crate::model::ProblemInstance;
use crate::net::{BandwidthEstimator, Link};
use crate::obs::{DropReason, Recorder, PID_VIRTUAL, PID_WALL};
use crate::runtime::Manifest;
use crate::serving::clock::SimClock;
use crate::serving::node::{Completion, ExecJob, ServerNode};
use crate::sim::{AdmissionQueue, FrameClock};
use crate::util::rng::Rng;
use anyhow::{Context, Result};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::channel;
use std::sync::{Arc, Mutex};

/// Configuration of one serving run (paper testbed defaults).
#[derive(Clone, Debug)]
pub struct ServingConfig {
    pub artifacts_dir: String,
    /// Edge servers (paper testbed: 2 RP4s).
    pub num_edge: usize,
    /// Tiers placed on each edge (SqueezeNet-class models).
    pub edge_tiers: Vec<String>,
    /// Tiers placed on the cloud (empty = all manifest tiers).
    pub cloud_tiers: Vec<String>,
    /// Scheduling policy name (`gus`, `random`, `local-all`, ...).
    pub scheduler: String,
    /// Total requests to generate.
    pub total_requests: usize,
    /// Arrival window: requests arrive Poisson over this span (sim ms).
    pub window_ms: f64,
    /// Decision frame (paper: 3000 ms).
    pub frame_ms: f64,
    /// Admission queue capacity per edge (paper: 4).
    pub queue_capacity: usize,
    /// Executor workers per edge (paper: 3 threads).
    pub gamma_edge: usize,
    pub gamma_cloud: usize,
    /// Images forwardable per edge per frame (paper: 10).
    pub eta_edge: f64,
    pub eta_cloud: f64,
    /// QoS thresholds, fixed for all requests as in the paper.
    pub min_accuracy_pct: f64,
    pub deadline_ms: f64,
    /// Calibrated processing delays for the fastest tier (ms).
    pub edge_proc_base_ms: f64,
    pub cloud_proc_base_ms: f64,
    /// Per-tier-step processing slowdown.
    pub tier_slowdown: f64,
    /// Simulated ms per real ms (1.0 = real time).
    pub time_scale: f64,
    pub seed: u64,
}

impl Default for ServingConfig {
    fn default() -> Self {
        ServingConfig {
            artifacts_dir: "artifacts".into(),
            num_edge: 2,
            edge_tiers: vec!["tiny".into(), "small".into()],
            cloud_tiers: Vec::new(),
            scheduler: "gus".into(),
            total_requests: 120,
            window_ms: 60_000.0,
            frame_ms: 3_000.0,
            queue_capacity: 4,
            gamma_edge: 3,
            gamma_cloud: 8,
            eta_edge: 10.0,
            eta_cloud: 48.0,
            min_accuracy_pct: 50.0,
            deadline_ms: 5_300.0,
            edge_proc_base_ms: 1_300.0,
            cloud_proc_base_ms: 300.0,
            tier_slowdown: 1.10,
            time_scale: 50.0,
            seed: 7,
        }
    }
}

/// A generated user request while it waits for a decision.
struct ServeRequest {
    id: u64,
    arrival_sim_ms: f64,
    payload_bytes: u64,
    images: Vec<f32>,
}

/// The assembled serving system.
pub struct ServingSystem {
    cfg: ServingConfig,
    manifest: Manifest,
    tiers: Vec<String>,
    recorder: Option<Arc<Recorder>>,
}

impl ServingSystem {
    pub fn new(cfg: ServingConfig) -> Result<ServingSystem> {
        let manifest = Manifest::load(&cfg.artifacts_dir)?;
        let tiers = manifest.tiers();
        for t in cfg.edge_tiers.iter().chain(cfg.cloud_tiers.iter()) {
            if !tiers.contains(t) {
                anyhow::bail!("tier {t} not in manifest (has {tiers:?})");
            }
        }
        Ok(ServingSystem { cfg, manifest, tiers, recorder: None })
    }

    /// Attach an observability recorder; a disabled one is free.
    pub fn with_recorder(mut self, recorder: Arc<Recorder>) -> ServingSystem {
        self.recorder = Some(recorder);
        self
    }

    /// The scheduler-visible catalog: one service ("classify") whose tiers
    /// are the real compiled artifacts, with paper-calibrated delays.
    fn catalog(&self) -> ServiceCatalog {
        let cfg = &self.cfg;
        let profiles: Vec<TierProfile> = self
            .tiers
            .iter()
            .enumerate()
            .map(|(i, tier)| {
                let acc = self
                    .manifest
                    .find(tier, 1)
                    .map(|a| a.profile_accuracy_pct)
                    .unwrap_or(50.0);
                let slow = cfg.tier_slowdown.powi(i as i32);
                let mut proc = [0.0; ServerClass::COUNT];
                for (ci, speed) in [1.15, 1.0, 0.85].iter().enumerate() {
                    proc[ci] = cfg.edge_proc_base_ms * slow * speed;
                }
                proc[ServerClass::Cloud.index()] = cfg.cloud_proc_base_ms * slow;
                TierProfile {
                    accuracy_pct: acc,
                    proc_ms: proc,
                    comp_cost: 1.0,
                    comm_cost: 1.0,
                    model_bytes: 0,
                }
            })
            .collect();
        ServiceCatalog::from_profiles(vec![profiles])
    }

    fn placement(&self) -> Placement {
        let cfg = &self.cfg;
        let tier_idx = |name: &str| TierId(self.tiers.iter().position(|t| t == name).unwrap()); // lint:allow(unwrap) — self.tiers is built from these same names
        let mut on = Vec::new();
        let mut cloud_flags = Vec::new();
        for _ in 0..cfg.num_edge {
            let mut pairs: Vec<(ServiceId, TierId)> =
                cfg.edge_tiers.iter().map(|t| (ServiceId(0), tier_idx(t))).collect();
            pairs.sort();
            on.push(pairs);
            cloud_flags.push(false);
        }
        // Cloud: explicit tier list, or everything.
        if cfg.cloud_tiers.is_empty() {
            on.push(Vec::new());
            cloud_flags.push(true);
        } else {
            let mut pairs: Vec<(ServiceId, TierId)> =
                cfg.cloud_tiers.iter().map(|t| (ServiceId(0), tier_idx(t))).collect();
            pairs.sort();
            on.push(pairs);
            cloud_flags.push(false);
        }
        Placement::explicit(on, cloud_flags)
    }

    fn cloud_tier_names(&self) -> Vec<String> {
        if self.cfg.cloud_tiers.is_empty() {
            self.tiers.clone()
        } else {
            self.cfg.cloud_tiers.clone()
        }
    }

    /// Run to completion; returns the end-to-end metrics.
    pub fn run(&self) -> Result<ServingMetrics> {
        let cfg = &self.cfg;
        let scheduler: Box<dyn Scheduler + Send + Sync> = scheduler_by_name(&cfg.scheduler)
            .with_context(|| format!("unknown scheduler {}", cfg.scheduler))?;
        let clock = SimClock::new(cfg.time_scale);
        let catalog = self.catalog();
        let placement = self.placement();
        let cloud_id = cfg.num_edge; // last server
        let num_servers = cfg.num_edge + 1;

        // Observability: Some only for an enabled recorder, so the
        // request path pays one branch per site when off.
        let recorder = self.recorder.clone().filter(|r| r.is_enabled());
        if let Some(r) = &recorder {
            for reason in DropReason::ALL {
                r.declare("edgeus_serve_dropped_total", "reason", reason.as_str());
            }
        }
        let wall_t0 = std::time::Instant::now();

        // Metrics plumbing.
        let metrics = Arc::new(Mutex::new(ServingMetrics::default()));
        let finished = Arc::new(AtomicUsize::new(0));
        let (completion_tx, completion_rx) = channel::<(Completion, f64, f64)>();

        // Collector thread: scores Def. II.1 satisfaction per completion.
        let collector = {
            let metrics = Arc::clone(&metrics);
            let finished = Arc::clone(&finished);
            let recorder = recorder.clone();
            std::thread::spawn(move || {
                while let Ok((c, a_min, c_max)) = completion_rx.recv() {
                    let ok = c.accuracy_pct >= a_min && c.completion_ms <= c_max;
                    let mut m = metrics.lock().unwrap();
                    m.served += 1;
                    if ok {
                        m.satisfied += 1;
                    }
                    let kind = if c.served_local {
                        m.local += 1;
                        "local"
                    } else if c.served_by_cloud {
                        m.offload_cloud += 1;
                        "cloud"
                    } else {
                        m.offload_peer += 1;
                        "peer"
                    };
                    m.latency.record(c.completion_ms);
                    m.inference.record(c.inference_real_ms.max(1e-3));
                    drop(m);
                    if let Some(r) = &recorder {
                        // Full lifecycle span: arrival → reply, in sim time.
                        let track = match kind {
                            "local" => 0,
                            "cloud" => 1,
                            _ => 2,
                        };
                        r.span(
                            "serve",
                            "serve",
                            PID_VIRTUAL,
                            track,
                            c.arrival_sim_ms,
                            c.completion_ms,
                            c.request_id,
                        );
                        r.add("edgeus_serve_served_total", 1.0);
                        if ok {
                            r.add("edgeus_serve_satisfied_total", 1.0);
                        }
                        r.add_labeled("edgeus_serve_assigned_total", "kind", kind, 1.0);
                        r.add("edgeus_serve_inference_ms_total", c.inference_real_ms.max(0.0));
                    }
                    finished.fetch_add(1, Ordering::SeqCst);
                }
            })
        };

        // Wrap node completions with the fixed QoS thresholds.
        let (node_tx, node_rx) = channel::<Completion>();
        let qos_fwd = {
            let completion_tx = completion_tx.clone();
            let a_min = cfg.min_accuracy_pct;
            let c_max = cfg.deadline_ms;
            std::thread::spawn(move || {
                while let Ok(c) = node_rx.recv() {
                    let _ = completion_tx.send((c, a_min, c_max));
                }
            })
        };

        // Spawn server nodes (edges cycle through classes, like the sim).
        let mut nodes: Vec<Arc<ServerNode>> = Vec::new();
        for e in 0..cfg.num_edge {
            let class = ServerClass::EDGE_CLASSES[e % 3];
            nodes.push(Arc::new(ServerNode::spawn(
                e,
                class,
                &cfg.artifacts_dir,
                cfg.edge_tiers.clone(),
                cfg.gamma_edge,
                clock,
                node_tx.clone(),
            )?));
        }
        nodes.push(Arc::new(ServerNode::spawn(
            cloud_id,
            ServerClass::Cloud,
            &cfg.artifacts_dir,
            self.cloud_tier_names(),
            cfg.gamma_cloud,
            clock,
            node_tx.clone(),
        )?));
        drop(node_tx);

        // Admission queues.
        let queues: Vec<Arc<Mutex<AdmissionQueue<ServeRequest>>>> = (0..cfg.num_edge)
            .map(|_| Arc::new(Mutex::new(AdmissionQueue::new(cfg.queue_capacity))))
            .collect();

        // Request generator.
        let generated = Arc::new(AtomicU64::new(0));
        let image_len = self.manifest.image_size * self.manifest.image_size
            * self.manifest.image_channels;
        let generator = {
            let queues: Vec<_> = queues.iter().map(Arc::clone).collect();
            let metrics = Arc::clone(&metrics);
            let finished = Arc::clone(&finished);
            let generated = Arc::clone(&generated);
            let recorder = recorder.clone();
            let total = cfg.total_requests;
            let window = cfg.window_ms;
            let seed = cfg.seed;
            std::thread::spawn(move || {
                let mut rng = Rng::new(seed);
                let mean_gap = window / total.max(1) as f64;
                for id in 0..total as u64 {
                    // Poisson arrivals: exponential inter-arrival gaps.
                    let gap = -mean_gap * (1.0 - rng.f64()).ln();
                    clock.sleep_ms(gap.min(mean_gap * 10.0));
                    let edge = rng.index(queues.len());
                    let images: Vec<f32> = (0..image_len).map(|_| rng.f64() as f32).collect();
                    let req = ServeRequest {
                        id,
                        arrival_sim_ms: clock.now_ms(),
                        payload_bytes: rng.u64_range(8_000, 20_000),
                        images,
                    };
                    let arrival_sim = req.arrival_sim_ms;
                    generated.fetch_add(1, Ordering::SeqCst);
                    let admitted = queues[edge].lock().unwrap().push(req, clock.now_ms());
                    if let Some(r) = &recorder {
                        r.instant("serve", "arrival", PID_VIRTUAL, edge as u32, arrival_sim, "", id);
                        r.add("edgeus_serve_arrivals_total", 1.0);
                    }
                    if !admitted {
                        // Bounded admission queue rejection: the only drop
                        // site outside the scheduler's decision.
                        let mut m = metrics.lock().unwrap();
                        m.add_drop(DropReason::QueueFull);
                        drop(m);
                        if let Some(r) = &recorder {
                            r.add_labeled(
                                "edgeus_serve_dropped_total",
                                "reason",
                                DropReason::QueueFull.as_str(),
                                1.0,
                            );
                            r.instant(
                                "serve",
                                "drop",
                                PID_VIRTUAL,
                                edge as u32,
                                arrival_sim,
                                DropReason::QueueFull.as_str(),
                                id,
                            );
                        }
                        finished.fetch_add(1, Ordering::SeqCst);
                    }
                }
            })
        };

        // Network links + bandwidth estimator (edge↔cloud path).
        let edge_cloud_link = Link::edge_cloud_default();
        let edge_edge_link = Link::edge_edge_default();
        let mut estimator = BandwidthEstimator::new(600.0);

        // Leader loop: decision frames. Scheduler working memory and the
        // schedule output live outside the loop so steady-state frames
        // reuse warm buffers (and the GUS rank cache) instead of
        // reallocating per decision.
        let mut frame = FrameClock::new(cfg.frame_ms);
        let mut leader_rng = Rng::new(cfg.seed ^ 0xD15BA7C4);
        let mut sched_scratch = SchedScratch::default();
        let mut schedule = Schedule::empty(0);
        let real_tick = std::time::Duration::from_secs_f64(
            (cfg.frame_ms / cfg.time_scale / 1e3 / 20.0).max(0.0005),
        );
        let mut dispatch_threads: Vec<std::thread::JoinHandle<()>> = Vec::new();
        loop {
            let done = finished.load(Ordering::SeqCst) >= cfg.total_requests;
            if done {
                break;
            }
            std::thread::sleep(real_tick);
            let now = clock.now_ms();
            let any_full = queues.iter().any(|q| q.lock().unwrap().is_full());
            let any_waiting = queues.iter().any(|q| !q.lock().unwrap().is_empty());
            if !frame.should_fire(now, any_full) || !any_waiting {
                continue;
            }
            frame.fired(now);

            // Drain all queues into one joint decision problem.
            let mut pending: Vec<(usize, ServeRequest, f64)> = Vec::new();
            for (e, q) in queues.iter().enumerate() {
                for (req, tq) in q.lock().unwrap().drain(now) {
                    pending.push((e, req, tq));
                }
            }
            if pending.is_empty() {
                continue;
            }

            // Build the scheduler's instance with residual capacities.
            let mut servers = Vec::with_capacity(num_servers);
            for (j, node) in nodes.iter().enumerate() {
                let base_gamma =
                    if j == cloud_id { cfg.gamma_cloud } else { cfg.gamma_edge } as f64;
                let free = (base_gamma - node.inflight() as f64).max(0.0);
                let eta = if j == cloud_id { cfg.eta_cloud } else { cfg.eta_edge };
                servers.push(Server::new(j, node.class).with_capacities(free, eta));
            }
            // Comm matrix from the current bandwidth estimate.
            let mean_payload = 14_000u64;
            let cloud_ms = estimator.expected_delay_ms(mean_payload) + edge_cloud_link.propagation_ms;
            let edge_ms = edge_edge_link.expected_delay_ms(mean_payload);
            let mut comm = vec![vec![0.0; num_servers]; num_servers];
            for a in 0..num_servers {
                for b in 0..num_servers {
                    if a == b {
                        continue;
                    }
                    comm[a][b] =
                        if a == cloud_id || b == cloud_id { cloud_ms } else { edge_ms };
                }
            }
            let topology = Topology::explicit(servers, comm);
            let requests: Vec<Request> = pending
                .iter()
                .enumerate()
                .map(|(i, (e, req, tq))| {
                    Request::new(i, 0, *e)
                        .with_qos(cfg.min_accuracy_pct, cfg.deadline_ms)
                        .with_queue_delay(*tq)
                        .with_payload(req.payload_bytes)
                })
                .collect();
            // The topology is rebuilt each frame (capacities move), but
            // the catalog and placement are borrowed — no per-frame
            // deep clone of the service profiles.
            let inst = ProblemInstance::from_parts(
                std::borrow::Cow::Owned(topology),
                std::borrow::Cow::Borrowed(&catalog),
                std::borrow::Cow::Borrowed(&placement),
                requests,
            )
            .with_normalization(100.0, 12_000.0);
            let sched_w0 =
                recorder.as_ref().map(|_| wall_t0.elapsed().as_secs_f64() * 1e3);
            scheduler.schedule_into(&inst, &mut leader_rng, &mut sched_scratch, &mut schedule);
            if let (Some(r), Some(w0)) = (&recorder, sched_w0) {
                let w1 = wall_t0.elapsed().as_secs_f64() * 1e3;
                r.span("leader", "frame.schedule", PID_WALL, 0, w0, w1 - w0, 0);
                r.instant("leader", "decision", PID_VIRTUAL, 0, now, "", 0);
                r.sample("edgeus_serve_frame_requests", PID_VIRTUAL, 0, now, inst.requests.len() as f64);
            }
            // Post-hoc decision explanation: needed for the trace and to
            // classify scheduler-rejected requests by drop reason.
            let needs_explain =
                recorder.is_some() || schedule.slots.iter().any(|s| s.is_none());
            let explain = if needs_explain { Some(explain_schedule(&inst, &schedule)) } else { None };
            if let (Some(r), Some(ex)) = (&recorder, &explain) {
                r.add("edgeus_serve_candidates_total", ex.candidates_considered as f64);
            }

            // Dispatch.
            for (i, (e, req, _tq)) in pending.into_iter().enumerate() {
                match &schedule.slots[i] {
                    None => {
                        let reason = explain
                            .as_ref()
                            .map(|ex| match ex.outcomes[i].outcome {
                                Outcome::Dropped(r) => r,
                                _ => DropReason::Policy,
                            })
                            .unwrap_or(DropReason::Policy);
                        let mut m = metrics.lock().unwrap();
                        m.add_drop(reason);
                        drop(m);
                        if let Some(r) = &recorder {
                            r.add_labeled(
                                "edgeus_serve_dropped_total",
                                "reason",
                                reason.as_str(),
                                1.0,
                            );
                            r.instant(
                                "serve",
                                "drop",
                                PID_VIRTUAL,
                                e as u32,
                                now,
                                reason.as_str(),
                                req.id,
                            );
                        }
                        finished.fetch_add(1, Ordering::SeqCst);
                    }
                    Some(a) => {
                        self.dispatch(
                            a,
                            req,
                            &nodes,
                            cloud_id,
                            clock,
                            &edge_cloud_link,
                            &edge_edge_link,
                            &mut estimator,
                            &mut leader_rng,
                            &mut dispatch_threads,
                        );
                    }
                }
            }
            // Reap finished transfer threads opportunistically.
            dispatch_threads.retain(|h| !h.is_finished());
        }

        generator.join().expect("generator panicked"); // lint:allow(unwrap) — propagate worker panics
        for h in dispatch_threads {
            let _ = h.join();
        }
        // Shut down nodes (drops engine threads), then the collector.
        for node in nodes {
            match Arc::try_unwrap(node) {
                Ok(n) => n.shutdown(),
                Err(_) => {} // a transfer thread still holds it; it exits on its own
            }
        }
        let _ = qos_fwd.join();
        drop(completion_tx);
        let _ = collector.join();

        let mut m = Arc::try_unwrap(metrics)
            .map(|m| m.into_inner().unwrap())
            .unwrap_or_else(|arc| arc.lock().unwrap().clone());
        m.total_requests = cfg.total_requests as u64;
        m.wall_ms = clock.now_ms();
        // Every generated request must be accounted for exactly once.
        m.check_conservation().map_err(anyhow::Error::msg)?;
        Ok(m)
    }

    #[allow(clippy::too_many_arguments)]
    fn dispatch(
        &self,
        a: &Assignment,
        req: ServeRequest,
        nodes: &[Arc<ServerNode>],
        cloud_id: usize,
        clock: SimClock,
        edge_cloud_link: &Link,
        edge_edge_link: &Link,
        estimator: &mut BandwidthEstimator,
        rng: &mut Rng,
        transfers: &mut Vec<std::thread::JoinHandle<()>>,
    ) {
        let tier_name = self.tiers[a.candidate.tier.0].clone();
        let target = Arc::clone(&nodes[a.candidate.server.0]);
        let profile_proc = {
            let class = target.class;
            // Same calibration as `catalog()`.
            let slow = self.cfg.tier_slowdown.powi(a.candidate.tier.0 as i32);
            if class.is_cloud() {
                self.cfg.cloud_proc_base_ms * slow
            } else {
                let speed = [1.15, 1.0, 0.85][class.index()];
                self.cfg.edge_proc_base_ms * slow * speed
            }
        };
        let job = ExecJob {
            request_id: req.id,
            arrival_sim_ms: req.arrival_sim_ms,
            tier: tier_name,
            proc_ms: profile_proc,
            accuracy_pct: a.candidate.accuracy_pct,
            images: req.images,
            served_local: !a.candidate.offloaded,
        };
        if !a.candidate.offloaded {
            target.submit(job);
            return;
        }
        // Offload: sample the real link, feed the estimator, and forward
        // after the (scaled) transfer delay.
        let link = if a.candidate.server.0 == cloud_id { edge_cloud_link } else { edge_edge_link };
        let (delay_ms, realized_bw) = link.transfer(req.payload_bytes, rng);
        if a.candidate.server.0 == cloud_id {
            estimator.observe(realized_bw);
        }
        if let Some(r) = self.recorder.as_deref().filter(|r| r.is_enabled()) {
            r.span(
                "serve",
                "transfer",
                PID_VIRTUAL,
                a.candidate.server.0 as u32,
                clock.now_ms(),
                delay_ms,
                job.request_id,
            );
            r.add("edgeus_serve_transfers_total", 1.0);
        }
        transfers.push(std::thread::spawn(move || {
            clock.sleep_ms(delay_ms);
            target.submit(job);
        }));
    }
}

/// Fig. 1(e)–(h): sweep the offered load for each policy on the live
/// system, reporting satisfied / local / cloud / peer percentages.
pub struct TestbedExperiment {
    pub base: ServingConfig,
    pub policies: Vec<String>,
    pub loads: Vec<usize>,
    /// Optional recorder, attached to the first run of the sweep (tracing
    /// every run would interleave unrelated sweeps in one trace).
    pub recorder: Option<Arc<Recorder>>,
}

impl Default for TestbedExperiment {
    fn default() -> Self {
        TestbedExperiment {
            base: ServingConfig::default(),
            policies: vec![
                "gus".into(),
                "random".into(),
                "local-all".into(),
                "offload-all".into(),
            ],
            loads: vec![60, 120, 240, 360],
            recorder: None,
        }
    }
}

/// Result of the testbed sweep: one series per panel (e)–(h).
pub struct TestbedResult {
    pub satisfied: crate::metrics::Series,
    pub local: crate::metrics::Series,
    pub cloud: crate::metrics::Series,
    pub peer: crate::metrics::Series,
    /// Raw metrics per (policy, load).
    pub raw: Vec<(String, usize, ServingMetrics)>,
}

impl TestbedExperiment {
    pub fn run(&self) -> Result<TestbedResult> {
        let xs: Vec<f64> = self.loads.iter().map(|l| *l as f64).collect();
        let mut satisfied = crate::metrics::Series::new("requests", "satisfied users (%)", xs.clone());
        let mut local = crate::metrics::Series::new("requests", "locally processed (%)", xs.clone());
        let mut cloud = crate::metrics::Series::new("requests", "offloaded to cloud (%)", xs.clone());
        let mut peer = crate::metrics::Series::new("requests", "offloaded to peers (%)", xs);
        let nan = vec![f64::NAN; self.loads.len()];
        let mut raw = Vec::new();
        let mut recorder = self.recorder.clone();
        for policy in &self.policies {
            let mut s = Vec::new();
            let mut l = Vec::new();
            let mut c = Vec::new();
            let mut p = Vec::new();
            for &load in &self.loads {
                let mut cfg = self.base.clone();
                cfg.scheduler = policy.clone();
                cfg.total_requests = load;
                let mut system = ServingSystem::new(cfg)?;
                if let Some(r) = recorder.take() {
                    system = system.with_recorder(r);
                }
                let metrics = system.run()?;
                s.push(metrics.satisfied_pct());
                l.push(metrics.local_pct());
                c.push(metrics.cloud_pct());
                p.push(metrics.peer_pct());
                raw.push((policy.clone(), load, metrics));
            }
            satisfied.push_policy(policy, s, nan.clone());
            local.push_policy(policy, l, nan.clone());
            cloud.push_policy(policy, c, nan.clone());
            peer.push_policy(policy, p, nan.clone());
        }
        Ok(TestbedResult { satisfied, local, cloud, peer, raw })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_is_paper_calibrated() {
        let c = ServingConfig::default();
        assert_eq!(c.num_edge, 2);
        assert_eq!(c.queue_capacity, 4);
        assert_eq!(c.frame_ms, 3000.0);
        assert_eq!(c.gamma_edge, 3);
        assert_eq!(c.eta_edge, 10.0);
        assert_eq!(c.min_accuracy_pct, 50.0);
        assert_eq!(c.edge_proc_base_ms, 1300.0);
        assert_eq!(c.cloud_proc_base_ms, 300.0);
    }

    // Full-system tests live in rust/tests/serving_e2e.rs (they need the
    // compiled artifacts).
}
