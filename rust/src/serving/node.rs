//! Server nodes of the live serving runtime: each node owns a PJRT
//! inference thread (loading only its placed artifacts) and a pool of γ
//! executor workers that emulate the node's processing-delay profile
//! while running *real* EdgeNet inference for every request.

use crate::model::server::ServerClass;
use crate::runtime::InferenceEngine;
use crate::serving::clock::SimClock;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// An inference request sent to a node's PJRT thread.
pub struct InferJob {
    pub tier: String,
    pub images: Vec<f32>,
    pub reply: Sender<anyhow::Result<crate::runtime::InferenceResult>>,
}

/// Handle to a pool of threads each owning an [`InferenceEngine`].
///
/// The xla types are not `Sync`; confining them to dedicated threads
/// keeps the rest of the system plain `Send` channels. A pool (rather
/// than a single engine thread) lets a node's γ executor workers overlap
/// real PJRT executions, matching the paper's multi-threaded testbed
/// servers.
pub struct InferenceHandle {
    tx: Sender<InferJob>,
    joins: Vec<std::thread::JoinHandle<()>>,
}

impl InferenceHandle {
    /// Spawn one engine thread (see [`InferenceHandle::spawn_pool`]).
    pub fn spawn(artifacts_dir: &str, tiers: Vec<String>) -> anyhow::Result<InferenceHandle> {
        Self::spawn_pool(artifacts_dir, tiers, 1)
    }

    /// Spawn `n` engine threads sharing one job queue, each loading the
    /// batch-1 artifacts for `tiers`.
    pub fn spawn_pool(
        artifacts_dir: &str,
        tiers: Vec<String>,
        n: usize,
    ) -> anyhow::Result<InferenceHandle> {
        assert!(n > 0);
        let (tx, rx): (Sender<InferJob>, Receiver<InferJob>) = channel();
        let shared_rx = Arc::new(Mutex::new(rx));
        let (ready_tx, ready_rx) = channel::<anyhow::Result<()>>();
        let mut joins = Vec::with_capacity(n);
        for t in 0..n {
            let dir = artifacts_dir.to_string();
            let tiers = tiers.clone();
            let rx = Arc::clone(&shared_rx);
            let ready_tx = ready_tx.clone();
            joins.push(
                std::thread::Builder::new()
                    .name(format!("pjrt-engine{t}"))
                    .spawn(move || {
                        let engine = match InferenceEngine::load_filtered(&dir, |a| {
                            a.batch == 1 && tiers.iter().any(|t| *t == a.tier)
                        }) {
                            Ok(e) => e,
                            Err(e) => {
                                let _ = ready_tx.send(Err(e));
                                return;
                            }
                        };
                        // Warm-up before signalling ready: first
                        // executions pay one-time buffer/layout costs
                        // that must not leak into the serving budget.
                        let warm = vec![0.0f32; engine.manifest.image_size
                            * engine.manifest.image_size
                            * engine.manifest.image_channels];
                        for tier in engine.manifest.tiers() {
                            if engine
                                .manifest
                                .find(&tier, 1)
                                .map(|a| engine.has(&a.name))
                                .unwrap_or(false)
                            {
                                let _ = engine.infer_tier(&tier, 1, &warm);
                            }
                        }
                        let _ = ready_tx.send(Ok(()));
                        loop {
                            let job = {
                                let guard = rx.lock().unwrap();
                                guard.recv()
                            };
                            let Ok(job) = job else { break };
                            let result = engine.infer_tier(&job.tier, 1, &job.images);
                            let _ = job.reply.send(result);
                        }
                    })?,
            );
        }
        drop(ready_tx);
        for _ in 0..n {
            ready_rx.recv().expect("engine thread died during load")?; // lint:allow(unwrap) — propagate engine-thread panics
        }
        Ok(InferenceHandle { tx, joins })
    }

    /// Spawn `n` mock engine threads that answer every job instantly with
    /// canned logits — the `--synthetic` serving mode. Keeps the full
    /// thread/channel topology of the real path (jobs still cross the
    /// engine queue) so scenario replay, parity tests, and CI smoke runs
    /// exercise the real concurrency structure without compiled
    /// artifacts or a PJRT backend.
    pub fn spawn_synthetic(num_classes: usize, n: usize) -> anyhow::Result<InferenceHandle> {
        assert!(n > 0);
        let (tx, rx): (Sender<InferJob>, Receiver<InferJob>) = channel();
        let shared_rx = Arc::new(Mutex::new(rx));
        let mut joins = Vec::with_capacity(n);
        for t in 0..n {
            let rx = Arc::clone(&shared_rx);
            joins.push(
                std::thread::Builder::new()
                    .name(format!("mock-engine{t}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        let Ok(job) = job else { break };
                        let _ = job.reply.send(Ok(crate::runtime::InferenceResult {
                            logits: vec![0.0; num_classes],
                            batch: 1,
                            num_classes,
                            execute_ms: 0.0,
                        }));
                    })?,
            );
        }
        Ok(InferenceHandle { tx, joins })
    }

    /// Run one image synchronously through the node's engine.
    pub fn infer(
        &self,
        tier: &str,
        images: Vec<f32>,
    ) -> anyhow::Result<crate::runtime::InferenceResult> {
        let (reply, rx) = channel();
        self.tx
            .send(InferJob { tier: tier.to_string(), images, reply })
            .map_err(|_| anyhow::anyhow!("inference thread gone"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("inference thread dropped reply"))?
    }

    pub fn sender(&self) -> Sender<InferJob> {
        self.tx.clone()
    }
}

impl Drop for InferenceHandle {
    fn drop(&mut self) {
        // Close the channel, then join the engine threads.
        let (tx, _) = channel();
        drop(std::mem::replace(&mut self.tx, tx));
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
    }
}

/// One executed request, reported to the metrics collector.
#[derive(Clone, Debug)]
pub struct Completion {
    pub request_id: u64,
    /// Simulated arrival time at the covering edge (ms) — lets the
    /// collector emit a full arrival→reply trace span.
    pub arrival_sim_ms: f64,
    /// Simulated end-to-end completion time (arrival → logits), ms.
    pub completion_ms: f64,
    /// Profile accuracy of the tier that served it (percent).
    pub accuracy_pct: f64,
    /// Real PJRT execute latency (ms, unscaled).
    pub inference_real_ms: f64,
    pub served_local: bool,
    pub served_by_cloud: bool,
    pub predicted_class: usize,
}

/// A job dispatched to a node's executor pool.
pub struct ExecJob {
    pub request_id: u64,
    /// Simulated arrival time at the covering edge (ms).
    pub arrival_sim_ms: f64,
    /// Tier chosen by the scheduler.
    pub tier: String,
    /// Profile processing delay to emulate for this (tier, node) pair, ms.
    pub proc_ms: f64,
    pub accuracy_pct: f64,
    pub images: Vec<f32>,
    pub served_local: bool,
}

/// A running server node: γ executor workers + 1 PJRT thread.
pub struct ServerNode {
    pub id: usize,
    pub class: ServerClass,
    pub tiers: Vec<String>,
    job_tx: Sender<ExecJob>,
    /// Jobs admitted but not yet completed (executor queue + in service).
    /// Includes dispatch reservations (see [`ServerNode::reserve`]) so the
    /// leader's residual-γ view already counts work still in transfer.
    inflight: Arc<AtomicUsize>,
    /// Scenario availability: a down node stays running (jobs already in
    /// service finish) but receives no new dispatches.
    up: Arc<AtomicBool>,
    workers: Vec<std::thread::JoinHandle<()>>,
    _engine: InferenceHandle,
}

impl ServerNode {
    /// Spawn the node. `gamma` = executor workers (the paper testbed used
    /// 3 inference threads per edge); the engine pool is sized so γ
    /// concurrent requests do not serialize behind one PJRT thread.
    #[allow(clippy::too_many_arguments)]
    pub fn spawn(
        id: usize,
        class: ServerClass,
        artifacts_dir: &str,
        tiers: Vec<String>,
        gamma: usize,
        clock: SimClock,
        completions: Sender<Completion>,
    ) -> anyhow::Result<ServerNode> {
        assert!(gamma > 0);
        let engines = gamma.min(4);
        let engine = InferenceHandle::spawn_pool(artifacts_dir, tiers.clone(), engines)?;
        Self::spawn_with_engine(id, class, tiers, engine, gamma, clock, completions)
    }

    /// Spawn the node around an already-built engine handle (real pool or
    /// [`InferenceHandle::spawn_synthetic`]).
    #[allow(clippy::too_many_arguments)]
    pub fn spawn_with_engine(
        id: usize,
        class: ServerClass,
        tiers: Vec<String>,
        engine: InferenceHandle,
        gamma: usize,
        clock: SimClock,
        completions: Sender<Completion>,
    ) -> anyhow::Result<ServerNode> {
        assert!(gamma > 0);
        let (job_tx, job_rx) = channel::<ExecJob>();
        let shared_rx = Arc::new(Mutex::new(job_rx));
        let inflight = Arc::new(AtomicUsize::new(0));
        let is_cloud = class.is_cloud();
        let mut workers = Vec::with_capacity(gamma);
        for w in 0..gamma {
            let rx = Arc::clone(&shared_rx);
            let engine_tx = engine.sender();
            let completions = completions.clone();
            let inflight = Arc::clone(&inflight);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("node{id}-exec{w}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        let Ok(job) = job else { break };
                        let t0 = Instant::now();
                        // Real inference through PJRT.
                        let (reply, reply_rx) = channel();
                        let infer_ms;
                        let mut predicted = 0usize;
                        if engine_tx
                            .send(InferJob { tier: job.tier.clone(), images: job.images, reply })
                            .is_ok()
                        {
                            match reply_rx.recv() {
                                Ok(Ok(res)) => {
                                    infer_ms = res.execute_ms;
                                    predicted = res.predictions()[0];
                                }
                                _ => infer_ms = 0.0,
                            }
                        } else {
                            infer_ms = 0.0;
                        }
                        // Emulate the node's calibrated processing delay:
                        // the real inference time counts toward it.
                        let spent_sim = clock.to_sim_ms(t0.elapsed());
                        clock.sleep_ms(job.proc_ms - spent_sim);
                        let completion_ms = clock.now_ms() - job.arrival_sim_ms;
                        inflight.fetch_sub(1, Ordering::SeqCst);
                        let _ = completions.send(Completion {
                            request_id: job.request_id,
                            arrival_sim_ms: job.arrival_sim_ms,
                            completion_ms,
                            accuracy_pct: job.accuracy_pct,
                            inference_real_ms: infer_ms,
                            served_local: job.served_local,
                            served_by_cloud: is_cloud,
                            predicted_class: predicted,
                        });
                    })?,
            );
        }
        Ok(ServerNode {
            id,
            class,
            tiers,
            job_tx,
            inflight,
            up: Arc::new(AtomicBool::new(true)),
            workers,
            _engine: engine,
        })
    }

    /// Enqueue a job on this node's executor pool.
    pub fn submit(&self, job: ExecJob) {
        self.inflight.fetch_add(1, Ordering::SeqCst);
        self.submit_reserved(job);
    }

    /// Reserve one inflight slot ahead of an asynchronous dispatch (the
    /// job is still crossing a transfer link). Pair with
    /// [`ServerNode::submit_reserved`] or [`ServerNode::release`]; the
    /// reservation keeps the next frame's residual γ honest about work
    /// already committed to this node.
    pub fn reserve(&self) {
        self.inflight.fetch_add(1, Ordering::SeqCst);
    }

    /// Give back a reservation without submitting (dispatch redirected or
    /// dropped).
    pub fn release(&self) {
        self.inflight.fetch_sub(1, Ordering::SeqCst);
    }

    /// Reserve a slot only if committed inflight stays within `cap` —
    /// the bound cloud dispatches and redirect fallbacks use so a wave
    /// of mid-transfer failovers can never overcommit the cloud past
    /// its γ. CAS loop (not add-then-rollback) so a concurrent reader
    /// never observes inflight above `cap` even transiently.
    pub fn try_reserve(&self, cap: usize) -> bool {
        let mut cur = self.inflight.load(Ordering::SeqCst);
        loop {
            if cur >= cap {
                return false;
            }
            match self.inflight.compare_exchange(
                cur,
                cur + 1,
                Ordering::SeqCst,
                Ordering::SeqCst,
            ) {
                Ok(_) => return true,
                Err(now) => cur = now,
            }
        }
    }

    /// Enqueue a job whose inflight slot was already reserved.
    pub fn submit_reserved(&self, job: ExecJob) {
        if self.job_tx.send(job).is_err() {
            self.inflight.fetch_sub(1, Ordering::SeqCst);
        }
    }

    /// Jobs admitted but not yet finished.
    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::SeqCst)
    }

    /// Scenario availability flag (leader-synced from the live topology).
    pub fn is_up(&self) -> bool {
        self.up.load(Ordering::SeqCst)
    }

    pub fn set_up(&self, up: bool) {
        self.up.store(up, Ordering::SeqCst);
    }

    pub fn inflight_handle(&self) -> Arc<AtomicUsize> {
        Arc::clone(&self.inflight)
    }

    /// Close the job queue and join the workers.
    pub fn shutdown(mut self) {
        let (tx, _) = channel();
        drop(std::mem::replace(&mut self.job_tx, tx));
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}
