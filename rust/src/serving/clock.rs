//! Scaled simulation clock for the live serving runtime.
//!
//! The paper's testbed operates on multi-second delays (3 s decision
//! frames, 1.3 s edge inferences). The serving runtime reproduces those
//! dynamics in *scaled* time: one simulated millisecond = `1/scale` real
//! milliseconds, so a full Fig. 1(e)–(h) run finishes in seconds while
//! preserving every ratio between queueing, communication, processing and
//! deadline times. `scale = 1.0` runs in true real time.

use std::time::Instant;

/// Monotonic scaled clock shared by all serving threads.
#[derive(Clone, Copy, Debug)]
pub struct SimClock {
    start: Instant,
    /// Simulated ms per real ms.
    pub scale: f64,
}

impl SimClock {
    pub fn new(scale: f64) -> SimClock {
        assert!(scale > 0.0);
        SimClock { start: Instant::now(), scale }
    }

    /// Current simulated time (ms since start).
    pub fn now_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3 * self.scale
    }

    /// Block the calling thread for `sim_ms` simulated milliseconds.
    pub fn sleep_ms(&self, sim_ms: f64) {
        if sim_ms <= 0.0 {
            return;
        }
        std::thread::sleep(std::time::Duration::from_secs_f64(sim_ms / self.scale / 1e3));
    }

    /// Convert an elapsed real duration to simulated ms.
    pub fn to_sim_ms(&self, real: std::time::Duration) -> f64 {
        real.as_secs_f64() * 1e3 * self.scale
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_advances_scaled() {
        let c = SimClock::new(100.0);
        let t0 = c.now_ms();
        std::thread::sleep(std::time::Duration::from_millis(20));
        let dt = c.now_ms() - t0;
        // 20 real ms at 100x ≈ 2000 sim ms (generous CI bounds).
        assert!(dt > 1000.0 && dt < 30_000.0, "dt={dt}");
    }

    #[test]
    fn sleep_scales_down() {
        let c = SimClock::new(1000.0);
        let t0 = Instant::now();
        c.sleep_ms(1000.0); // 1 real ms
        let real = t0.elapsed().as_secs_f64() * 1e3;
        assert!(real < 200.0, "slept {real} real ms");
    }

    #[test]
    fn zero_sleep_returns_immediately() {
        let c = SimClock::new(1.0);
        let t0 = Instant::now();
        c.sleep_ms(0.0);
        c.sleep_ms(-5.0);
        assert!(t0.elapsed().as_millis() < 50);
    }

    #[test]
    fn to_sim_ms_converts() {
        let c = SimClock::new(50.0);
        let d = std::time::Duration::from_millis(10);
        assert!((c.to_sim_ms(d) - 500.0).abs() < 1e-6);
    }
}
