//! Runtime: the PJRT bridge between the rust coordinator and the
//! AOT-compiled EdgeNet artifacts. Python is build-time only; after
//! `make artifacts` the serving binary is self-contained.

pub mod engine;
pub mod manifest;

pub use engine::{InferenceEngine, InferenceResult};
pub use manifest::{ArtifactInfo, Manifest};
