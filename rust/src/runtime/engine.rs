//! PJRT inference engine: loads the HLO-text artifacts produced by
//! `python/compile/aot.py`, compiles them once on the CPU PJRT client,
//! and executes them from the serving hot path.
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`), not a
//! serialized proto: jax ≥ 0.5 emits 64-bit instruction ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids. See
//! /opt/xla-example/README.md and DESIGN.md.

use crate::runtime::manifest::{ArtifactInfo, Manifest};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::time::Instant;

#[cfg(not(feature = "xla"))]
use xla_stub as xla;

/// Build-time stand-in for the external `xla` crate (absent from the
/// offline registry — see DESIGN.md §Substitutions). It mirrors exactly
/// the API surface this module consumes so the whole crate compiles and
/// tests without PJRT; `PjRtClient::cpu()` fails with a clear message, so
/// every artifact-dependent path (serving, `--load`, the e2e tests)
/// degrades to its documented "run `make artifacts`" skip behaviour.
/// Building with `--features xla` (plus the real dependency) swaps this
/// out without touching the engine code.
#[cfg(not(feature = "xla"))]
mod xla_stub {
    use anyhow::{bail, Result};

    pub struct PjRtClient;

    impl PjRtClient {
        pub fn cpu() -> Result<PjRtClient> {
            bail!("edgeus was built without the `xla` feature: PJRT execution is unavailable")
        }

        pub fn platform_name(&self) -> String {
            "stub".to_string()
        }

        pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
            bail!("xla feature disabled")
        }
    }

    pub struct HloModuleProto;

    impl HloModuleProto {
        pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
            bail!("xla feature disabled")
        }
    }

    pub struct XlaComputation;

    impl XlaComputation {
        pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
            XlaComputation
        }
    }

    pub struct PjRtLoadedExecutable;

    impl PjRtLoadedExecutable {
        pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
            bail!("xla feature disabled")
        }
    }

    pub struct PjRtBuffer;

    impl PjRtBuffer {
        pub fn to_literal_sync(&self) -> Result<Literal> {
            bail!("xla feature disabled")
        }
    }

    pub struct Literal;

    impl Literal {
        pub fn vec1(_xs: &[f32]) -> Literal {
            Literal
        }

        pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
            bail!("xla feature disabled")
        }

        pub fn to_tuple1(self) -> Result<Literal> {
            bail!("xla feature disabled")
        }

        pub fn to_vec<T>(&self) -> Result<Vec<T>> {
            bail!("xla feature disabled")
        }
    }
}

/// One compiled executable plus its metadata.
struct Loaded {
    info: ArtifactInfo,
    exe: xla::PjRtLoadedExecutable,
}

/// A PJRT CPU client with a set of compiled EdgeNet artifacts.
///
/// Not `Sync`: each serving thread that needs inference owns its own
/// engine (or talks to one through a channel). Compilation happens once
/// in `load`; `infer` is allocation-light.
pub struct InferenceEngine {
    client: xla::PjRtClient,
    loaded: HashMap<String, Loaded>,
    pub manifest: Manifest,
}

/// Result of one inference call.
#[derive(Clone, Debug)]
pub struct InferenceResult {
    /// Logits, row-major `(batch, num_classes)`.
    pub logits: Vec<f32>,
    pub batch: usize,
    pub num_classes: usize,
    /// Wall time of the PJRT execute call (ms).
    pub execute_ms: f64,
}

impl InferenceResult {
    /// Argmax per image.
    pub fn predictions(&self) -> Vec<usize> {
        (0..self.batch)
            .map(|b| {
                let row = &self.logits[b * self.num_classes..(b + 1) * self.num_classes];
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(i, _)| i)
                    .unwrap_or(0)
            })
            .collect()
    }
}

impl InferenceEngine {
    /// Load and compile every artifact in the manifest.
    pub fn load(dir: &str) -> Result<InferenceEngine> {
        Self::load_filtered(dir, |_| true)
    }

    /// Load only artifacts matching `keep` (e.g. one server's placement).
    pub fn load_filtered(
        dir: &str,
        keep: impl Fn(&ArtifactInfo) -> bool,
    ) -> Result<InferenceEngine> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut loaded = HashMap::new();
        for info in manifest.artifacts.iter().filter(|a| keep(a)) {
            let path = manifest.path_of(info);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parsing HLO text {path}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling {}", info.name))?;
            loaded.insert(info.name.clone(), Loaded { info: info.clone(), exe });
        }
        if loaded.is_empty() {
            bail!("no artifacts loaded from {dir}");
        }
        Ok(InferenceEngine { client, loaded, manifest })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifact_names(&self) -> Vec<String> {
        let mut names: Vec<String> = self.loaded.keys().cloned().collect();
        names.sort();
        names
    }

    pub fn has(&self, name: &str) -> bool {
        self.loaded.contains_key(name)
    }

    pub fn info(&self, name: &str) -> Option<&ArtifactInfo> {
        self.loaded.get(name).map(|l| &l.info)
    }

    /// Run one batch through artifact `name`.
    ///
    /// `images` is row-major `(batch, H, W, C)` f32 and must match the
    /// artifact's input shape exactly (batching/padding is the caller's
    /// job — see `serving::batcher`).
    pub fn infer(&self, name: &str, images: &[f32]) -> Result<InferenceResult> {
        let entry = self
            .loaded
            .get(name)
            .with_context(|| format!("artifact {name} not loaded"))?;
        let expect: usize = entry.info.input_shape.iter().product();
        if images.len() != expect {
            bail!(
                "{name}: input has {} elements, artifact expects {:?} = {expect}",
                images.len(),
                entry.info.input_shape
            );
        }
        let dims: Vec<i64> = entry.info.input_shape.iter().map(|d| *d as i64).collect();
        let input = xla::Literal::vec1(images)
            .reshape(&dims)
            .context("reshaping input literal")?;
        let t0 = Instant::now();
        let result = entry.exe.execute::<xla::Literal>(&[input])?[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let execute_ms = t0.elapsed().as_secs_f64() * 1e3;
        // aot.py lowers with return_tuple=True → 1-tuple of logits.
        let logits_lit = result.to_tuple1().context("unwrapping result tuple")?;
        let logits = logits_lit.to_vec::<f32>().context("reading logits")?;
        let batch = entry.info.output_shape[0];
        let num_classes = entry.info.output_shape[1];
        if logits.len() != batch * num_classes {
            bail!("{name}: got {} logits, expected {}", logits.len(), batch * num_classes);
        }
        Ok(InferenceResult { logits, batch, num_classes, execute_ms })
    }

    /// Convenience: infer via (tier, batch) lookup.
    pub fn infer_tier(&self, tier: &str, batch: usize, images: &[f32]) -> Result<InferenceResult> {
        let info = self
            .manifest
            .find(tier, batch)
            .with_context(|| format!("no artifact for tier={tier} batch={batch}"))?;
        let name = info.name.clone();
        self.infer(&name, images)
    }
}

#[cfg(test)]
mod tests {
    //! Engine tests need built artifacts; they are exercised by the
    //! integration suite (`rust/tests/integration.rs`) which skips with a
    //! clear message when `artifacts/` is absent. Pure-logic pieces are
    //! tested here.
    use super::*;

    #[test]
    fn predictions_argmax() {
        let r = InferenceResult {
            logits: vec![0.1, 0.9, -1.0, 3.0, 2.0, 2.5],
            batch: 2,
            num_classes: 3,
            execute_ms: 0.0,
        };
        assert_eq!(r.predictions(), vec![1, 0]);
    }

    #[test]
    fn predictions_single() {
        let r = InferenceResult { logits: vec![5.0], batch: 1, num_classes: 1, execute_ms: 0.0 };
        assert_eq!(r.predictions(), vec![0]);
    }
}
