//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! rust runtime. Parsed from `artifacts/manifest.json`.

use crate::util::json::Json;
use anyhow::{bail, Context, Result};

/// One compiled (tier, batch) artifact.
#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub name: String,
    pub tier: String,
    pub batch: usize,
    pub file: String,
    pub input_shape: Vec<usize>,
    pub output_shape: Vec<usize>,
    /// Calibrated top-1 accuracy exposed to the scheduler (percent).
    pub profile_accuracy_pct: f64,
    pub params: u64,
    pub flops_per_image: u64,
    pub sha256: String,
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: String,
    pub image_size: usize,
    pub image_channels: usize,
    pub num_classes: usize,
    pub artifacts: Vec<ArtifactInfo>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &str) -> Result<Manifest> {
        let path = format!("{dir}/manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path} — run `make artifacts` first"))?;
        Self::parse(dir, &text)
    }

    pub fn parse(dir: &str, text: &str) -> Result<Manifest> {
        let j = Json::parse(text).context("parsing manifest.json")?;
        if j.get("format").as_str() != Some("hlo-text") {
            bail!("unsupported artifact format {:?}", j.get("format"));
        }
        let mut artifacts = Vec::new();
        for a in j.get("artifacts").as_arr().context("manifest: artifacts[]")? {
            let shape = |key: &str| -> Result<Vec<usize>> {
                a.get(key)
                    .as_arr()
                    .with_context(|| format!("artifact {key}"))?
                    .iter()
                    .map(|v| v.as_usize().context("shape dim"))
                    .collect()
            };
            artifacts.push(ArtifactInfo {
                name: a.get("name").as_str().context("artifact name")?.to_string(),
                tier: a.get("tier").as_str().context("artifact tier")?.to_string(),
                batch: a.get("batch").as_usize().context("artifact batch")?,
                file: a.get("file").as_str().context("artifact file")?.to_string(),
                input_shape: shape("input_shape")?,
                output_shape: shape("output_shape")?,
                profile_accuracy_pct: a
                    .get("profile_accuracy_pct")
                    .as_f64()
                    .context("profile accuracy")?,
                params: a.get("params").as_i64().unwrap_or(0) as u64,
                flops_per_image: a.get("flops_per_image").as_i64().unwrap_or(0) as u64,
                sha256: a.get("sha256").as_str().unwrap_or("").to_string(),
            });
        }
        if artifacts.is_empty() {
            bail!("manifest lists no artifacts");
        }
        Ok(Manifest {
            dir: dir.to_string(),
            image_size: j.get("image_size").as_usize().unwrap_or(32),
            image_channels: j.get("image_channels").as_usize().unwrap_or(3),
            num_classes: j.get("num_classes").as_usize().unwrap_or(10),
            artifacts,
        })
    }

    /// A manifest that needs no files on disk: the accuracy ladder the
    /// serving runtime uses when inference is mocked (`--synthetic`), so
    /// scenario replay / parity tests / CI smoke run without compiled
    /// artifacts or a PJRT backend. Tier accuracies bracket the default
    /// 50% QoS floor the same way the paper's ladder does.
    pub fn synthetic() -> Manifest {
        let tier = |name: &str, acc: f64, params: u64| ArtifactInfo {
            name: format!("synthetic_{name}_b1"),
            tier: name.to_string(),
            batch: 1,
            file: format!("synthetic_{name}_b1.hlo.txt"),
            input_shape: vec![1, 8, 8, 1],
            output_shape: vec![1, 10],
            profile_accuracy_pct: acc,
            params,
            flops_per_image: params * 2,
            sha256: String::new(),
        };
        Manifest {
            dir: "<synthetic>".to_string(),
            image_size: 8,
            image_channels: 1,
            num_classes: 10,
            artifacts: vec![
                tier("tiny", 40.0, 7_000),
                tier("small", 52.0, 30_000),
                tier("base", 63.0, 100_000),
            ],
        }
    }

    /// Artifact for a (tier, batch) pair.
    pub fn find(&self, tier: &str, batch: usize) -> Option<&ArtifactInfo> {
        self.artifacts.iter().find(|a| a.tier == tier && a.batch == batch)
    }

    /// Distinct tiers, in manifest (= ladder) order.
    pub fn tiers(&self) -> Vec<String> {
        let mut tiers = Vec::new();
        for a in &self.artifacts {
            if !tiers.contains(&a.tier) {
                tiers.push(a.tier.clone());
            }
        }
        tiers
    }

    /// Batch sizes available for a tier, ascending.
    pub fn batches_of(&self, tier: &str) -> Vec<usize> {
        let mut b: Vec<usize> = self
            .artifacts
            .iter()
            .filter(|a| a.tier == tier)
            .map(|a| a.batch)
            .collect();
        b.sort_unstable();
        b
    }

    pub fn path_of(&self, info: &ArtifactInfo) -> String {
        format!("{}/{}", self.dir, info.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": "hlo-text", "image_size": 32, "image_channels": 3,
      "num_classes": 10, "param_seed": 1,
      "artifacts": [
        {"name": "edgenet_tiny_b1", "tier": "tiny", "batch": 1,
         "file": "edgenet_tiny_b1.hlo.txt", "input_shape": [1,32,32,3],
         "output_shape": [1,10], "profile_accuracy_pct": 40.0,
         "params": 7162, "flops_per_image": 789696, "sha256": "ab", "bytes": 10},
        {"name": "edgenet_tiny_b4", "tier": "tiny", "batch": 4,
         "file": "edgenet_tiny_b4.hlo.txt", "input_shape": [4,32,32,3],
         "output_shape": [4,10], "profile_accuracy_pct": 40.0,
         "params": 7162, "flops_per_image": 789696, "sha256": "cd", "bytes": 10},
        {"name": "edgenet_base_b1", "tier": "base", "batch": 1,
         "file": "edgenet_base_b1.hlo.txt", "input_shape": [1,32,32,3],
         "output_shape": [1,10], "profile_accuracy_pct": 63.0,
         "params": 100000, "flops_per_image": 9000000, "sha256": "ef", "bytes": 10}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse("/tmp/a", SAMPLE).unwrap();
        assert_eq!(m.artifacts.len(), 3);
        assert_eq!(m.image_size, 32);
        assert_eq!(m.tiers(), vec!["tiny", "base"]);
        assert_eq!(m.batches_of("tiny"), vec![1, 4]);
        let a = m.find("tiny", 4).unwrap();
        assert_eq!(a.input_shape, vec![4, 32, 32, 3]);
        assert_eq!(m.path_of(a), "/tmp/a/edgenet_tiny_b4.hlo.txt");
        assert!(m.find("tiny", 8).is_none());
    }

    #[test]
    fn rejects_wrong_format() {
        let bad = SAMPLE.replace("hlo-text", "proto");
        assert!(Manifest::parse("/tmp", &bad).is_err());
    }

    #[test]
    fn rejects_empty_artifacts() {
        let bad = r#"{"format":"hlo-text","artifacts":[]}"#;
        assert!(Manifest::parse("/tmp", bad).is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Manifest::parse("/tmp", "{nope").is_err());
    }

    #[test]
    fn synthetic_ladder_brackets_default_qos_floor() {
        let m = Manifest::synthetic();
        assert_eq!(m.tiers(), vec!["tiny", "small", "base"]);
        let accs: Vec<f64> =
            m.artifacts.iter().map(|a| a.profile_accuracy_pct).collect();
        assert!(accs.windows(2).all(|w| w[0] < w[1]), "ladder ascends: {accs:?}");
        assert!(accs.first().copied() < Some(50.0) && accs.last().copied() > Some(50.0));
        for t in m.tiers() {
            assert!(m.find(&t, 1).is_some(), "every tier serves batch 1");
        }
    }
}
