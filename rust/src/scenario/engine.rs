//! The live half of the scenario subsystem: replays a [`Script`] against
//! a running world, mutating the DES's `Topology`/`Placement` in place at
//! decision-frame boundaries so schedulers always see the current state.
//!
//! Design invariants:
//!
//! * **Frame-boundary application** — `advance(now, …)` is called at each
//!   decision; every event with `at_ms <= now` applies exactly once, in
//!   script order. Between decisions the world is frozen, which is what
//!   the paper's frame-granular control plane would observe anyway.
//! * **Restorability** — `ServerUp` restores the exact pre-outage
//!   capacities (the `Server::up` flag masks them, nothing is
//!   overwritten), and `BandwidthDrift` scales against a baseline
//!   snapshot of the comm matrix, so `factor = 1.0` is a bit-exact
//!   recovery.
//! * **Determinism** — the engine draws randomness only through the
//!   caller's [`Rng`] (for weighted covering-edge choice), so a DES run
//!   with a script is exactly as reproducible as one without.

use crate::model::service::{Placement, ServiceId, TierId};
use crate::model::{ServerId, Topology};
use crate::scenario::script::{EventKind, Script, ScriptedEvent};
use crate::util::rng::Rng;
use crate::workload::pick_weighted;

/// Replays one script over one run. Create per DES run.
pub struct ScenarioEngine {
    script: Script,
    /// Next unapplied event (events are time-sorted by `Script::new`).
    cursor: usize,
    /// Pre-scenario comm matrix; `BandwidthDrift` scales against this.
    baseline_comm: Vec<Vec<f64>>,
    /// Arrival weight per edge *position* (index into the edge list).
    weights: Vec<f64>,
    /// Server id of each edge position.
    edge_ids: Vec<usize>,
    burst_multiplier: f64,
    burst_until_ms: f64,
    /// Catalog bounds for validating `PlacementChange` targets.
    num_services: usize,
    num_tiers: usize,
    /// Latest drift factor applied to any edge↔cloud link (1.0 = nominal).
    /// The serving runtime biases its `BandwidthEstimator` by this instead
    /// of reading the comm matrix (which it derives live per frame).
    backhaul_drift: f64,
    /// Latest drift factor applied to any edge↔edge link (1.0 = nominal).
    peer_drift: f64,
    /// `(world time applied, event label)` for every applied event, in
    /// application order — the phase boundaries for scenario-segmented
    /// metrics reporting.
    applied_log: Vec<(f64, &'static str)>,
    /// Total events applied so far (skipped out-of-range events excluded).
    pub applied_total: u64,
}

impl ScenarioEngine {
    pub fn new(
        script: Script,
        topology: &Topology,
        num_services: usize,
        num_tiers: usize,
    ) -> ScenarioEngine {
        let edge_ids: Vec<usize> = topology.edge_ids().iter().map(|s| s.0).collect();
        ScenarioEngine {
            cursor: 0,
            baseline_comm: topology.comm_matrix(),
            weights: vec![1.0; edge_ids.len()],
            edge_ids,
            burst_multiplier: 1.0,
            burst_until_ms: f64::NEG_INFINITY,
            num_services,
            num_tiers,
            backhaul_drift: 1.0,
            peer_drift: 1.0,
            applied_log: Vec::new(),
            applied_total: 0,
            script,
        }
    }

    /// Apply every event due at or before `now_ms`. Returns how many
    /// applied at this boundary (out-of-range targets are skipped, not
    /// counted — `Script::validate` exists to reject those up front).
    pub fn advance(
        &mut self,
        now_ms: f64,
        topology: &mut Topology,
        placement: &mut Placement,
    ) -> u64 {
        self.advance_traced(now_ms, topology, placement, None)
    }

    /// [`ScenarioEngine::advance`], additionally dropping a world-event
    /// marker (instant + labeled counter) on `obs` per applied event.
    pub fn advance_traced(
        &mut self,
        now_ms: f64,
        topology: &mut Topology,
        placement: &mut Placement,
        obs: Option<&crate::obs::Recorder>,
    ) -> u64 {
        let mut applied = 0u64;
        while self.cursor < self.script.events.len()
            && self.script.events[self.cursor].at_ms <= now_ms
        {
            let ev = self.script.events[self.cursor].clone();
            self.cursor += 1;
            if self.apply(&ev, topology, placement) {
                applied += 1;
                self.applied_log.push((now_ms, ev.kind.label()));
                if let Some(r) = obs {
                    let label = ev.kind.label();
                    r.instant("scenario", label, crate::obs::PID_VIRTUAL, 0, now_ms, "", 0);
                    r.add_labeled("edgeus_scenario_events_total", "kind", label, 1.0);
                }
            }
        }
        self.applied_total += applied;
        applied
    }

    fn apply(
        &mut self,
        ev: &ScriptedEvent,
        topology: &mut Topology,
        placement: &mut Placement,
    ) -> bool {
        match &ev.kind {
            EventKind::LoadBurst { rate_multiplier, duration_ms } => {
                self.burst_multiplier = *rate_multiplier;
                self.burst_until_ms = ev.at_ms + duration_ms;
                true
            }
            EventKind::ServerDown { server } => self.set_up(*server, false, topology),
            EventKind::ServerUp { server } => self.set_up(*server, true, topology),
            EventKind::BandwidthDrift { link, factor } => {
                let n = topology.len();
                let (mut hit_backhaul, mut hit_peer) = (false, false);
                for a in 0..n {
                    let a_cloud = topology.servers[a].is_cloud();
                    for b in 0..n {
                        if a == b {
                            continue;
                        }
                        let b_cloud = topology.servers[b].is_cloud();
                        if link.matches(a_cloud, b_cloud, a, b) {
                            topology.set_comm_ms(
                                ServerId(a),
                                ServerId(b),
                                self.baseline_comm[a][b] * factor,
                            );
                            if a_cloud || b_cloud {
                                hit_backhaul = true;
                            } else {
                                hit_peer = true;
                            }
                        }
                    }
                }
                if hit_backhaul {
                    self.backhaul_drift = *factor;
                }
                if hit_peer {
                    self.peer_drift = *factor;
                }
                true
            }
            EventKind::UserMobility { from_edge, to_edge, fraction } => {
                let n = self.weights.len();
                if *from_edge >= n || *to_edge >= n || from_edge == to_edge {
                    return false;
                }
                let moved = self.weights[*from_edge] * fraction.clamp(0.0, 1.0);
                self.weights[*from_edge] -= moved;
                self.weights[*to_edge] += moved;
                true
            }
            EventKind::PlacementChange { server, service, tier, add } => {
                if *server >= topology.len()
                    || *service >= self.num_services
                    || *tier >= self.num_tiers
                {
                    return false;
                }
                if *add {
                    placement.place(*server, ServiceId(*service), TierId(*tier));
                } else {
                    placement.evict(*server, ServiceId(*service), TierId(*tier));
                }
                true
            }
        }
    }

    fn set_up(&mut self, server: usize, up: bool, topology: &mut Topology) -> bool {
        if server >= topology.len() {
            return false;
        }
        // Route through the generation-bumping mutator so the rank cache
        // sees the outage; an already-in-state event still counts as
        // applied (the return value) but bumps nothing.
        topology.set_up(ServerId(server), up);
        true
    }

    /// Current arrival-rate multiplier (1.0 outside any burst window).
    /// The burst activates at the frame boundary where its event applies
    /// and expires by wall time, so the window end needs no second event.
    /// Bursts are last-writer-wins (see [`EventKind::LoadBurst`]).
    pub fn arrival_multiplier(&self, now_ms: f64) -> f64 {
        if now_ms < self.burst_until_ms {
            self.burst_multiplier
        } else {
            1.0
        }
    }

    /// Weighted covering-edge choice among *live* edges — users covered
    /// by a down edge re-home to the remaining coverage (their weight
    /// share is masked while the edge is down, restored when it returns).
    /// When every live edge has zero weight (e.g. mobility concentrated
    /// everything on an edge that then died), the fallback is uniform
    /// over the live edges only; a dead edge receives arrivals only in
    /// the total-blackout case where no edge is up at all.
    ///
    /// Availability is read from the live `topology` (the single source
    /// of truth the engine mutates), so out-of-band `Server::up` flips —
    /// e.g. the planned serving-runtime outage plumbing — are honoured.
    pub fn pick_edge(&self, topology: &Topology, rng: &mut Rng) -> usize {
        let live = |pos: usize| topology.servers[self.edge_ids[pos]].up;
        let masked: Vec<f64> = self
            .weights
            .iter()
            .enumerate()
            .map(|(pos, w)| if live(pos) { *w } else { 0.0 })
            .collect();
        if masked.iter().any(|w| *w > 0.0) {
            return pick_weighted(&masked, rng);
        }
        let uniform: Vec<f64> = (0..masked.len())
            .map(|pos| if live(pos) { 1.0 } else { 0.0 })
            .collect();
        pick_weighted(&uniform, rng)
    }

    /// The live burst window as `(rate multiplier, expires at ms)` —
    /// `(1.0, NEG_INFINITY)` outside any burst. The serving leader pushes
    /// this into the generator's shared arrival state at the frame
    /// boundary where the burst event applies.
    pub fn burst_window(&self) -> (f64, f64) {
        (self.burst_multiplier, self.burst_until_ms)
    }

    /// Latest drift factor applied to any edge↔cloud link (1.0 outside a
    /// drift). Lets the serving runtime bias its `BandwidthEstimator`
    /// the way the DES sees the scaled comm matrix.
    pub fn backhaul_drift(&self) -> f64 {
        self.backhaul_drift
    }

    /// Latest drift factor applied to any edge↔edge link (1.0 outside a
    /// drift).
    pub fn peer_drift(&self) -> f64 {
        self.peer_drift
    }

    /// Every applied event as `(world time applied, label)`, in
    /// application order — the phase boundaries for scenario-segmented
    /// metrics.
    pub fn applied_events(&self) -> &[(f64, &'static str)] {
        &self.applied_log
    }

    /// Write the effective arrival weight per edge *position* into `out`:
    /// mobility weights masked by liveness, falling back to uniform over
    /// the live edges when all live weight is zero — exactly the policy
    /// [`ScenarioEngine::pick_edge`] draws with. The serving generator
    /// thread samples from this snapshot between frame boundaries.
    pub fn edge_weights_into(&self, topology: &Topology, out: &mut Vec<f64>) {
        let live = |pos: usize| topology.servers[self.edge_ids[pos]].up;
        out.clear();
        out.extend(
            self.weights
                .iter()
                .enumerate()
                .map(|(pos, w)| if live(pos) { *w } else { 0.0 }),
        );
        if !out.iter().any(|w| *w > 0.0) {
            for (pos, w) in out.iter_mut().enumerate() {
                *w = if live(pos) { 1.0 } else { 0.0 };
            }
        }
    }

    /// Remaining unapplied events.
    pub fn pending(&self) -> usize {
        self.script.events.len() - self.cursor
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::service::{CatalogParams, ServiceCatalog};
    use crate::model::topology::TopologyParams;
    use crate::scenario::script::LinkClass;

    fn world() -> (Topology, Placement, ServiceCatalog) {
        let mut rng = Rng::new(3);
        let topology = Topology::paper_default(
            &TopologyParams { num_edge: 3, num_cloud: 1, ..Default::default() },
            &mut rng,
        );
        let catalog = ServiceCatalog::synthetic(
            &CatalogParams { num_services: 4, num_tiers: 3, ..Default::default() },
            &mut rng,
        );
        let classes: Vec<_> = topology.servers.iter().map(|s| s.class).collect();
        let placement = Placement::random(&catalog, &classes, &mut rng);
        (topology, placement, catalog)
    }

    fn engine_for(script: Script, topo: &Topology) -> ScenarioEngine {
        ScenarioEngine::new(script, topo, 4, 3)
    }

    #[test]
    fn events_apply_once_in_time_order() {
        let (mut topo, mut plc, _) = world();
        let script = Script::new(
            "s",
            vec![
                ScriptedEvent { at_ms: 1000.0, kind: EventKind::ServerDown { server: 0 } },
                ScriptedEvent { at_ms: 5000.0, kind: EventKind::ServerUp { server: 0 } },
            ],
        );
        let mut e = engine_for(script, &topo);
        assert_eq!(e.advance(500.0, &mut topo, &mut plc), 0);
        assert_eq!(e.advance(3000.0, &mut topo, &mut plc), 1);
        assert!(!topo.servers[0].up);
        // Same boundary again: nothing re-applies.
        assert_eq!(e.advance(3000.0, &mut topo, &mut plc), 0);
        assert_eq!(e.pending(), 1);
        assert_eq!(e.advance(6000.0, &mut topo, &mut plc), 1);
        assert!(topo.servers[0].up);
        assert_eq!(e.applied_total, 2);
    }

    #[test]
    fn server_up_restores_exact_capacities() {
        let (mut topo, mut plc, _) = world();
        let before = (topo.servers[2].gamma, topo.servers[2].eta);
        let script = Script::new(
            "s",
            vec![
                ScriptedEvent { at_ms: 0.0, kind: EventKind::ServerDown { server: 2 } },
                ScriptedEvent { at_ms: 10.0, kind: EventKind::ServerUp { server: 2 } },
            ],
        );
        let mut e = engine_for(script, &topo);
        e.advance(20.0, &mut topo, &mut plc);
        assert!(topo.servers[2].up);
        assert_eq!((topo.servers[2].gamma, topo.servers[2].eta), before);
    }

    #[test]
    fn bandwidth_drift_scales_and_restores_baseline() {
        let (mut topo, mut plc, _) = world();
        let baseline = topo.comm_matrix();
        let cloud = topo.cloud_ids()[0].0;
        let script = Script::new(
            "s",
            vec![
                ScriptedEvent {
                    at_ms: 0.0,
                    kind: EventKind::BandwidthDrift { link: LinkClass::EdgeCloud, factor: 10.0 },
                },
                ScriptedEvent {
                    at_ms: 100.0,
                    kind: EventKind::BandwidthDrift { link: LinkClass::EdgeCloud, factor: 1.0 },
                },
            ],
        );
        let mut e = engine_for(script, &topo);
        e.advance(0.0, &mut topo, &mut plc);
        assert_eq!(
            topo.comm_ms(ServerId(0), ServerId(cloud)),
            baseline[0][cloud] * 10.0
        );
        // Edge↔edge links untouched.
        assert_eq!(topo.comm_ms(ServerId(0), ServerId(1)), baseline[0][1]);
        e.advance(100.0, &mut topo, &mut plc);
        assert_eq!(topo.comm_matrix(), baseline, "factor 1.0 must be bit-exact");
    }

    #[test]
    fn mobility_moves_weight_and_outage_masks_it() {
        let (mut topo, mut plc, _) = world();
        let script = Script::new(
            "s",
            vec![
                ScriptedEvent {
                    at_ms: 0.0,
                    kind: EventKind::UserMobility { from_edge: 1, to_edge: 0, fraction: 1.0 },
                },
                ScriptedEvent {
                    at_ms: 0.0,
                    kind: EventKind::UserMobility { from_edge: 2, to_edge: 0, fraction: 1.0 },
                },
            ],
        );
        let mut e = engine_for(script, &topo);
        e.advance(0.0, &mut topo, &mut plc);
        // All weight sits on edge 0 now.
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            assert_eq!(e.pick_edge(&topo, &mut rng), 0);
        }
        // Down edge 0 (out-of-band flip — the engine reads the live
        // topology): all live weight is gone, so arrivals re-home
        // uniformly over the *live* edges — never to the dead one.
        topo.servers[0].up = false;
        let mut seen = [false; 3];
        for _ in 0..200 {
            seen[e.pick_edge(&topo, &mut rng)] = true;
        }
        assert!(!seen[0], "dead edge must receive no arrivals while others live");
        assert!(seen[1] && seen[2], "fallback must spread load: {seen:?}");
    }

    #[test]
    fn burst_window_multiplies_then_expires() {
        let (mut topo, mut plc, _) = world();
        let script = Script::new(
            "s",
            vec![ScriptedEvent {
                at_ms: 1000.0,
                kind: EventKind::LoadBurst { rate_multiplier: 6.0, duration_ms: 2000.0 },
            }],
        );
        let mut e = engine_for(script, &topo);
        assert_eq!(e.arrival_multiplier(1500.0), 1.0, "not applied yet");
        e.advance(1000.0, &mut topo, &mut plc);
        assert_eq!(e.arrival_multiplier(1500.0), 6.0);
        assert_eq!(e.arrival_multiplier(2999.0), 6.0);
        assert_eq!(e.arrival_multiplier(3000.0), 1.0, "window closed");
    }

    #[test]
    fn advance_traced_drops_markers_and_counters() {
        let (mut topo, mut plc, _) = world();
        let rec = crate::obs::Recorder::enabled(16);
        let script = Script::new(
            "s",
            vec![
                ScriptedEvent { at_ms: 0.0, kind: EventKind::ServerDown { server: 0 } },
                ScriptedEvent { at_ms: 0.0, kind: EventKind::ServerUp { server: 0 } },
            ],
        );
        let mut e = engine_for(script, &topo);
        assert_eq!(e.advance_traced(0.0, &mut topo, &mut plc, Some(&rec)), 2);
        let names: Vec<&str> = rec.events().iter().map(|ev| ev.name).collect();
        assert_eq!(names, vec!["server_down", "server_up"]);
        assert_eq!(
            rec.counter_value("edgeus_scenario_events_total", "kind", "server_down"),
            1.0
        );
    }

    #[test]
    fn placement_change_adds_and_evicts() {
        let (mut topo, mut plc, _) = world();
        // Force a known hole, then script it back in and out.
        plc.evict(0, ServiceId(1), TierId(2));
        let script = Script::new(
            "s",
            vec![
                ScriptedEvent {
                    at_ms: 0.0,
                    kind: EventKind::PlacementChange { server: 0, service: 1, tier: 2, add: true },
                },
                ScriptedEvent {
                    at_ms: 10.0,
                    kind: EventKind::PlacementChange { server: 0, service: 1, tier: 2, add: false },
                },
                // Out-of-range target: skipped, not applied.
                ScriptedEvent {
                    at_ms: 10.0,
                    kind: EventKind::PlacementChange { server: 0, service: 99, tier: 0, add: true },
                },
            ],
        );
        let mut e = engine_for(script, &topo);
        assert_eq!(e.advance(0.0, &mut topo, &mut plc), 1);
        assert!(plc.has(0, ServiceId(1), TierId(2)));
        assert_eq!(e.advance(10.0, &mut topo, &mut plc), 1, "bad target skipped");
        assert!(!plc.has(0, ServiceId(1), TierId(2)));
    }

    #[test]
    fn drift_factors_track_by_link_class_and_log_records_phases() {
        let (mut topo, mut plc, _) = world();
        let script = Script::new(
            "s",
            vec![
                ScriptedEvent {
                    at_ms: 0.0,
                    kind: EventKind::BandwidthDrift { link: LinkClass::EdgeCloud, factor: 30.0 },
                },
                ScriptedEvent {
                    at_ms: 100.0,
                    kind: EventKind::BandwidthDrift { link: LinkClass::EdgeEdge, factor: 2.0 },
                },
                ScriptedEvent {
                    at_ms: 200.0,
                    kind: EventKind::BandwidthDrift { link: LinkClass::All, factor: 1.0 },
                },
            ],
        );
        let mut e = engine_for(script, &topo);
        assert_eq!((e.backhaul_drift(), e.peer_drift()), (1.0, 1.0));
        e.advance(0.0, &mut topo, &mut plc);
        assert_eq!((e.backhaul_drift(), e.peer_drift()), (30.0, 1.0));
        e.advance(100.0, &mut topo, &mut plc);
        assert_eq!((e.backhaul_drift(), e.peer_drift()), (30.0, 2.0));
        e.advance(250.0, &mut topo, &mut plc);
        assert_eq!((e.backhaul_drift(), e.peer_drift()), (1.0, 1.0));
        assert_eq!(
            e.applied_events(),
            &[
                (0.0, "bandwidth_drift"),
                (100.0, "bandwidth_drift"),
                (250.0, "bandwidth_drift")
            ]
        );
    }

    #[test]
    fn edge_weights_mask_outages_with_live_uniform_fallback() {
        let (mut topo, mut plc, _) = world();
        let script = Script::new(
            "s",
            vec![ScriptedEvent {
                at_ms: 0.0,
                kind: EventKind::UserMobility { from_edge: 1, to_edge: 0, fraction: 1.0 },
            }],
        );
        let mut e = engine_for(script, &topo);
        e.advance(0.0, &mut topo, &mut plc);
        let mut w = Vec::new();
        e.edge_weights_into(&topo, &mut w);
        assert_eq!(w, vec![2.0, 0.0, 1.0]);
        // Edge 0 dies: its (concentrated) weight is masked.
        topo.servers[0].up = false;
        e.edge_weights_into(&topo, &mut w);
        assert_eq!(w, vec![0.0, 0.0, 1.0]);
        // All weighted edges die: uniform over the remaining live edge.
        topo.servers[2].up = false;
        e.edge_weights_into(&topo, &mut w);
        assert_eq!(w, vec![0.0, 1.0, 0.0]);
    }
}
