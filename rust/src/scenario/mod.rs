//! Dynamic scenario engine: scripted world events over the discrete-event
//! simulator, plus a parallel sweep runner.
//!
//! The paper evaluates a static snapshot — one topology, one load, one
//! decision round at a time. Production edge systems live in the dynamic
//! regime instead: bandwidth drifts, servers fail and recover, crowds
//! flash, users commute. This subsystem makes those worlds scriptable:
//!
//! * [`script`] — the event model ([`Script`] of typed [`ScriptedEvent`]s:
//!   `LoadBurst`, `ServerDown`/`ServerUp`, `BandwidthDrift`,
//!   `UserMobility`, `PlacementChange`), JSON load/save, and the built-in
//!   library (`flash-crowd`, `edge-failover`, `degraded-backhaul`,
//!   `commuter-wave`);
//! * [`engine`] — the [`ScenarioEngine`] that replays a script against a
//!   live `Topology`/`Placement` at decision-frame boundaries inside
//!   [`crate::sim::des`], so schedulers always see the mutated world;
//! * [`sweep`] — the parallel seeds × policies runner
//!   ([`run_sweep`]) with mean/CI aggregation and satisfaction-vs-time
//!   resampling ([`timeline_series`]), exposed as the `edgeus scenario`
//!   CLI subcommand and the scenario figures.
//!
//! See DESIGN.md §Scenario-engine for the full design notes.

pub mod engine;
pub mod script;
pub mod sweep;

pub use engine::ScenarioEngine;
pub use script::{EventKind, LinkClass, Script, ScriptedEvent, BUILTIN_NAMES};
pub use sweep::{run_sweep, timeline_on_grid, timeline_series, PolicySweep, SweepConfig};
