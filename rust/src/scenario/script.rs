//! The scenario event model: a time-ordered script of typed events that
//! the [`crate::scenario::ScenarioEngine`] applies to a live world while
//! the discrete-event simulator runs.
//!
//! Scripts serialize to/from JSON through [`crate::util::json`], so
//! experiments are exactly repeatable across machines (`edgeus scenario
//! --save s.json` / `--script s.json`), and a library of named built-in
//! scenarios covers the canonical dynamic regimes from the related work:
//! flash crowds, edge failover, backhaul degradation and commuter-style
//! user mobility. See DESIGN.md §Scenario-engine.

use crate::util::json::Json;
use anyhow::{bail, Context, Result};

/// Which directed links a [`EventKind::BandwidthDrift`] touches.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LinkClass {
    /// Every link with a cloud endpoint (the backhaul).
    EdgeCloud,
    /// Every edge↔edge peering link.
    EdgeEdge,
    /// Every link in the system.
    All,
    /// One directed link `a → b`.
    Pair { a: usize, b: usize },
}

impl LinkClass {
    /// Does the directed link `a → b` (with the given cloud-ness of its
    /// endpoints) belong to this class?
    pub fn matches(&self, a_is_cloud: bool, b_is_cloud: bool, a: usize, b: usize) -> bool {
        match self {
            LinkClass::All => true,
            LinkClass::EdgeCloud => a_is_cloud || b_is_cloud,
            LinkClass::EdgeEdge => !a_is_cloud && !b_is_cloud,
            LinkClass::Pair { a: pa, b: pb } => *pa == a && *pb == b,
        }
    }

    fn to_json(self) -> Json {
        match self {
            LinkClass::EdgeCloud => Json::str("edge-cloud"),
            LinkClass::EdgeEdge => Json::str("edge-edge"),
            LinkClass::All => Json::str("all"),
            LinkClass::Pair { a, b } => Json::obj(vec![
                ("a", Json::num(a as f64)),
                ("b", Json::num(b as f64)),
            ]),
        }
    }

    fn from_json(j: &Json) -> Result<LinkClass> {
        if let Some(s) = j.as_str() {
            return match s {
                "edge-cloud" => Ok(LinkClass::EdgeCloud),
                "edge-edge" => Ok(LinkClass::EdgeEdge),
                "all" => Ok(LinkClass::All),
                other => bail!("unknown link class {other:?}"),
            };
        }
        let a = j.get("a").as_usize().context("link: a")?;
        let b = j.get("b").as_usize().context("link: b")?;
        Ok(LinkClass::Pair { a, b })
    }
}

/// One typed world mutation.
#[derive(Clone, Debug, PartialEq)]
pub enum EventKind {
    /// Multiply the Poisson arrival rate by `rate_multiplier` for
    /// `duration_ms` after the event applies. Bursts are
    /// last-writer-wins: a later `LoadBurst` replaces any active one
    /// (window end included), so step-function load profiles are
    /// expressed as a sequence of bursts, each restating its level.
    LoadBurst { rate_multiplier: f64, duration_ms: f64 },
    /// Take a server (edge or cloud) out of service: it stops being a
    /// candidate target, its γ/η vanish, and covered users re-home to
    /// the remaining live edges.
    ServerDown { server: usize },
    /// Bring a previously downed server back (capacities restored).
    ServerUp { server: usize },
    /// Set every matching link's delay to `factor ×` its *baseline*
    /// (pre-scenario) delay. `factor = 1.0` restores the baseline
    /// exactly, so degrade/recover pairs round-trip bit-for-bit.
    BandwidthDrift { link: LinkClass, factor: f64 },
    /// Move `fraction` of `from_edge`'s current arrival weight to
    /// `to_edge` (indices into the edge list, i.e. edge positions).
    UserMobility { from_edge: usize, to_edge: usize, fraction: f64 },
    /// Add (`add = true`) or evict a (service, tier) replica on a server,
    /// visible to schedulers from the next decision frame on.
    PlacementChange { server: usize, service: usize, tier: usize, add: bool },
}

impl EventKind {
    /// Stable machine label, used as the JSON `type` tag.
    pub fn label(&self) -> &'static str {
        match self {
            EventKind::LoadBurst { .. } => "load_burst",
            EventKind::ServerDown { .. } => "server_down",
            EventKind::ServerUp { .. } => "server_up",
            EventKind::BandwidthDrift { .. } => "bandwidth_drift",
            EventKind::UserMobility { .. } => "user_mobility",
            EventKind::PlacementChange { .. } => "placement_change",
        }
    }
}

/// One event at its virtual-time trigger point.
#[derive(Clone, Debug, PartialEq)]
pub struct ScriptedEvent {
    pub at_ms: f64,
    pub kind: EventKind,
}

/// A named, time-ordered scenario script.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Script {
    pub name: String,
    pub events: Vec<ScriptedEvent>,
}

/// The built-in scenario library, in presentation order.
pub const BUILTIN_NAMES: [&str; 4] =
    ["flash-crowd", "edge-failover", "degraded-backhaul", "commuter-wave"];

/// Every JSON `type` tag, in declaration order (shared with `verify`).
pub const EVENT_TYPES: [&str; 6] = [
    "load_burst",
    "server_down",
    "server_up",
    "bandwidth_drift",
    "user_mobility",
    "placement_change",
];

/// The exact field set an event object of the given `type` may carry.
/// `None` for unknown types. Parsing is strict: anything outside this
/// list is a hard error, not a silent skip (a typoed `durationms`
/// must not quietly become an infinite burst).
pub fn allowed_event_fields(ty: &str) -> Option<&'static [&'static str]> {
    match ty {
        "load_burst" => Some(&["at_ms", "type", "rate_multiplier", "duration_ms"]),
        "server_down" | "server_up" => Some(&["at_ms", "type", "server"]),
        "bandwidth_drift" => Some(&["at_ms", "type", "link", "factor"]),
        "user_mobility" => Some(&["at_ms", "type", "from_edge", "to_edge", "fraction"]),
        "placement_change" => Some(&["at_ms", "type", "server", "service", "tier", "add"]),
        _ => None,
    }
}

/// Best-effort byte location of a quoted token in the source text, for
/// span-ish parse errors (the parsed `Json` tree does not retain
/// offsets; the raw text does).
fn span_note(src: Option<&str>, token: &str) -> String {
    let Some(text) = src else { return String::new() };
    match text.find(&format!("\"{token}\"")) {
        Some(off) => format!(" (byte {off})"),
        None => String::new(),
    }
}

impl Script {
    /// Build a script; events are sorted by trigger time (stable, so
    /// same-timestamp events keep authoring order).
    pub fn new(name: &str, events: Vec<ScriptedEvent>) -> Script {
        let mut s = Script { name: name.to_string(), events };
        s.events.sort_by(|a, b| a.at_ms.total_cmp(&b.at_ms));
        s
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Structural validation against a world size. The engine also skips
    /// out-of-range events defensively, but scripts loaded from files
    /// should fail loudly instead.
    pub fn validate(
        &self,
        num_servers: usize,
        num_edges: usize,
        num_services: usize,
        num_tiers: usize,
    ) -> Result<()> {
        for (i, ev) in self.events.iter().enumerate() {
            if !ev.at_ms.is_finite() || ev.at_ms < 0.0 {
                bail!("event {i}: non-finite or negative trigger time {}", ev.at_ms);
            }
            match &ev.kind {
                EventKind::LoadBurst { rate_multiplier, duration_ms } => {
                    let bad = !rate_multiplier.is_finite()
                        || *rate_multiplier <= 0.0
                        || !duration_ms.is_finite()
                        || *duration_ms < 0.0;
                    if bad {
                        bail!("event {i}: load_burst needs multiplier > 0 and duration >= 0");
                    }
                }
                EventKind::ServerDown { server } | EventKind::ServerUp { server } => {
                    if *server >= num_servers {
                        bail!("event {i}: server {server} out of range (< {num_servers})");
                    }
                }
                EventKind::BandwidthDrift { link, factor } => {
                    if !factor.is_finite() || *factor <= 0.0 {
                        bail!("event {i}: bandwidth_drift factor must be > 0");
                    }
                    if let LinkClass::Pair { a, b } = link {
                        if *a >= num_servers || *b >= num_servers || a == b {
                            bail!("event {i}: link pair ({a}, {b}) invalid");
                        }
                    }
                }
                EventKind::UserMobility { from_edge, to_edge, fraction } => {
                    if *from_edge >= num_edges || *to_edge >= num_edges {
                        bail!("event {i}: mobility edge out of range (< {num_edges})");
                    }
                    if from_edge == to_edge {
                        bail!("event {i}: mobility from_edge == to_edge ({from_edge})");
                    }
                    if !(0.0..=1.0).contains(fraction) {
                        bail!("event {i}: mobility fraction {fraction} not in [0, 1]");
                    }
                }
                EventKind::PlacementChange { server, service, tier, .. } => {
                    if *server >= num_servers || *service >= num_services || *tier >= num_tiers {
                        bail!("event {i}: placement_change target out of range");
                    }
                }
            }
        }
        Ok(())
    }

    // -- built-in library -------------------------------------------------

    /// Names of the built-in scenarios.
    pub fn builtin_names() -> &'static [&'static str] {
        &BUILTIN_NAMES
    }

    /// Instantiate a named built-in scenario against a horizon and edge
    /// count (event times scale with the horizon, targets with the edge
    /// count — the same name works for the 3-edge test world and the
    /// paper's 9-edge default).
    pub fn builtin(name: &str, horizon_ms: f64, num_edges: usize) -> Option<Script> {
        assert!(horizon_ms > 0.0 && num_edges > 0);
        let h = horizon_ms;
        let events = match name {
            // A sudden ×8 arrival surge for ~30% of the run.
            "flash-crowd" => vec![ScriptedEvent {
                at_ms: 0.25 * h,
                kind: EventKind::LoadBurst { rate_multiplier: 8.0, duration_ms: 0.30 * h },
            }],
            // The best-provisioned edge dies mid-run and comes back:
            // its users re-home, capacity shrinks, then recovers.
            // `paper_default` cycles classes Small/Medium/Large by index,
            // so the last index ≡ 2 (mod 3) is the EdgeLarge victim; with
            // fewer than three edges the last edge is the best available.
            "edge-failover" => {
                let victim = (0..num_edges)
                    .rev()
                    .find(|i| i % 3 == 2)
                    .unwrap_or(num_edges - 1);
                vec![
                    ScriptedEvent {
                        at_ms: 0.30 * h,
                        kind: EventKind::ServerDown { server: victim },
                    },
                    ScriptedEvent {
                        at_ms: 0.65 * h,
                        kind: EventKind::ServerUp { server: victim },
                    },
                ]
            }
            // The edge↔cloud backhaul degrades 30× and later recovers —
            // offloading to the cloud stops paying off in between.
            "degraded-backhaul" => vec![
                ScriptedEvent {
                    at_ms: 0.30 * h,
                    kind: EventKind::BandwidthDrift { link: LinkClass::EdgeCloud, factor: 30.0 },
                },
                ScriptedEvent {
                    at_ms: 0.70 * h,
                    kind: EventKind::BandwidthDrift { link: LinkClass::EdgeCloud, factor: 1.0 },
                },
            ],
            // Morning: users pour into "downtown" (edge 0) and load rises;
            // evening: they spread back out evenly. The evening fractions
            // 1/n, 1/(n-1), … redistribute edge 0's weight in equal parts.
            "commuter-wave" => {
                if num_edges < 2 {
                    return None;
                }
                let n = num_edges;
                let mut events = vec![ScriptedEvent {
                    at_ms: 0.20 * h,
                    kind: EventKind::LoadBurst { rate_multiplier: 2.0, duration_ms: 0.30 * h },
                }];
                for e in 1..n {
                    events.push(ScriptedEvent {
                        at_ms: 0.20 * h,
                        kind: EventKind::UserMobility { from_edge: e, to_edge: 0, fraction: 0.7 },
                    });
                }
                for e in 1..n {
                    events.push(ScriptedEvent {
                        at_ms: 0.60 * h,
                        kind: EventKind::UserMobility {
                            from_edge: 0,
                            to_edge: e,
                            fraction: 1.0 / (n - e + 1) as f64,
                        },
                    });
                }
                events
            }
            _ => return None,
        };
        Some(Script::new(name, events))
    }

    // -- JSON -------------------------------------------------------------

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            (
                "events",
                Json::arr(self.events.iter().map(|ev| {
                    let mut fields = vec![
                        ("at_ms", Json::num(ev.at_ms)),
                        ("type", Json::str(ev.kind.label())),
                    ];
                    match &ev.kind {
                        EventKind::LoadBurst { rate_multiplier, duration_ms } => {
                            fields.push(("rate_multiplier", Json::num(*rate_multiplier)));
                            fields.push(("duration_ms", Json::num(*duration_ms)));
                        }
                        EventKind::ServerDown { server } | EventKind::ServerUp { server } => {
                            fields.push(("server", Json::num(*server as f64)));
                        }
                        EventKind::BandwidthDrift { link, factor } => {
                            fields.push(("link", link.to_json()));
                            fields.push(("factor", Json::num(*factor)));
                        }
                        EventKind::UserMobility { from_edge, to_edge, fraction } => {
                            fields.push(("from_edge", Json::num(*from_edge as f64)));
                            fields.push(("to_edge", Json::num(*to_edge as f64)));
                            fields.push(("fraction", Json::num(*fraction)));
                        }
                        EventKind::PlacementChange { server, service, tier, add } => {
                            fields.push(("server", Json::num(*server as f64)));
                            fields.push(("service", Json::num(*service as f64)));
                            fields.push(("tier", Json::num(*tier as f64)));
                            fields.push(("add", Json::Bool(*add)));
                        }
                    }
                    Json::obj(fields)
                })),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Script> {
        Script::from_json_with_src(j, None)
    }

    /// Strict parse from an already-parsed tree. When `src` (the raw
    /// JSON text) is available, unknown-type/field errors carry the
    /// byte offset of the offending token.
    fn from_json_with_src(j: &Json, src: Option<&str>) -> Result<Script> {
        let name = j.get("name").as_str().unwrap_or("unnamed").to_string();
        let mut events = Vec::new();
        for (i, ev) in j
            .get("events")
            .as_arr()
            .context("script: events[]")?
            .iter()
            .enumerate()
        {
            let at_ms = ev.get("at_ms").as_f64().with_context(|| format!("event {i}: at_ms"))?;
            let ty = ev.get("type").as_str().with_context(|| format!("event {i}: type"))?;
            let allowed = match allowed_event_fields(ty) {
                Some(a) => a,
                None => bail!(
                    "event {i}: unknown event type {ty:?}{} (expected one of {})",
                    span_note(src, ty),
                    EVENT_TYPES.join(", ")
                ),
            };
            if let Some(obj) = ev.as_obj() {
                for key in obj.keys() {
                    if !allowed.contains(&key.as_str()) {
                        bail!(
                            "event {i}: unknown field {key:?} for {ty}{} (allowed: {})",
                            span_note(src, key),
                            allowed.join(", ")
                        );
                    }
                }
            }
            let kind = match ty {
                "load_burst" => EventKind::LoadBurst {
                    rate_multiplier: ev
                        .get("rate_multiplier")
                        .as_f64()
                        .context("rate_multiplier")?,
                    duration_ms: ev.get("duration_ms").as_f64().context("duration_ms")?,
                },
                "server_down" => EventKind::ServerDown {
                    server: ev.get("server").as_usize().context("server")?,
                },
                "server_up" => EventKind::ServerUp {
                    server: ev.get("server").as_usize().context("server")?,
                },
                "bandwidth_drift" => EventKind::BandwidthDrift {
                    link: LinkClass::from_json(ev.get("link"))?,
                    factor: ev.get("factor").as_f64().context("factor")?,
                },
                "user_mobility" => EventKind::UserMobility {
                    from_edge: ev.get("from_edge").as_usize().context("from_edge")?,
                    to_edge: ev.get("to_edge").as_usize().context("to_edge")?,
                    fraction: ev.get("fraction").as_f64().context("fraction")?,
                },
                "placement_change" => EventKind::PlacementChange {
                    server: ev.get("server").as_usize().context("server")?,
                    service: ev.get("service").as_usize().context("service")?,
                    tier: ev.get("tier").as_usize().context("tier")?,
                    // Strict like every sibling field: a missing or
                    // non-boolean `add` must not silently become an add.
                    add: ev.get("add").as_bool().context("add (must be a JSON boolean)")?,
                },
                other => bail!("event {i}: unknown type {other:?}"),
            };
            events.push(ScriptedEvent { at_ms, kind });
        }
        Ok(Script::new(&name, events))
    }

    pub fn save(&self, path: &str) -> Result<()> {
        std::fs::write(path, self.to_json().pretty())
            .with_context(|| format!("writing {path}"))
    }

    /// Parse a script from raw JSON text. Errors carry byte offsets:
    /// malformed JSON reports the parser's exact position, and unknown
    /// event types/fields report the offending token's location.
    pub fn parse(text: &str) -> Result<Script> {
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("{e}"))?;
        Script::from_json_with_src(&j, Some(text))
    }

    pub fn load(path: &str) -> Result<Script> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        Script::parse(&text).with_context(|| format!("parsing {path}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Script {
        Script::new(
            "sample",
            vec![
                ScriptedEvent {
                    at_ms: 9000.0,
                    kind: EventKind::ServerUp { server: 2 },
                },
                ScriptedEvent {
                    at_ms: 3000.0,
                    kind: EventKind::ServerDown { server: 2 },
                },
                ScriptedEvent {
                    at_ms: 1000.5,
                    kind: EventKind::LoadBurst { rate_multiplier: 4.0, duration_ms: 2000.0 },
                },
                ScriptedEvent {
                    at_ms: 4000.0,
                    kind: EventKind::BandwidthDrift {
                        link: LinkClass::Pair { a: 0, b: 3 },
                        factor: 2.5,
                    },
                },
                ScriptedEvent {
                    at_ms: 5000.0,
                    kind: EventKind::UserMobility { from_edge: 1, to_edge: 0, fraction: 0.5 },
                },
                ScriptedEvent {
                    at_ms: 6000.0,
                    kind: EventKind::PlacementChange { server: 1, service: 2, tier: 3, add: true },
                },
            ],
        )
    }

    #[test]
    fn new_sorts_by_time() {
        let s = sample();
        for w in s.events.windows(2) {
            assert!(w[0].at_ms <= w[1].at_ms);
        }
        assert_eq!(s.events[0].at_ms, 1000.5);
    }

    #[test]
    fn json_round_trip_is_exact() {
        let s = sample();
        let text = s.to_json().pretty();
        let s2 = Script::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(s, s2);
        // Compact form too.
        let s3 = Script::from_json(&Json::parse(&s.to_json().dump()).unwrap()).unwrap();
        assert_eq!(s, s3);
    }

    #[test]
    fn save_load_round_trip() {
        let dir = std::env::temp_dir().join("edgeus_script_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("s.json").to_string_lossy().to_string();
        let s = sample();
        s.save(&path).unwrap();
        assert_eq!(Script::load(&path).unwrap(), s);
    }

    #[test]
    fn every_builtin_instantiates_and_validates() {
        for name in Script::builtin_names() {
            let s = Script::builtin(name, 120_000.0, 9).unwrap_or_else(|| panic!("{name}"));
            assert!(!s.is_empty(), "{name} must script something");
            assert_eq!(&s.name, name);
            // Paper-default world: 10 servers, 9 edges.
            s.validate(10, 9, 100, 10).unwrap_or_else(|e| panic!("{name}: {e}"));
            // And the small test world.
            let small = Script::builtin(name, 30_000.0, 3).unwrap();
            small.validate(4, 3, 10, 4).unwrap();
        }
        assert!(Script::builtin("no-such-scenario", 1000.0, 3).is_none());
    }

    #[test]
    fn commuter_wave_redistributes_evenly() {
        // The evening fractions must spread edge 0's weight equally.
        let n = 4usize;
        let mut w = [3.1f64, 0.3, 0.3, 0.3];
        for e in 1..n {
            let f = 1.0 / (n - e + 1) as f64;
            let moved = w[0] * f;
            w[0] -= moved;
            w[e] += moved;
        }
        for e in 1..n {
            assert!((w[e] - w[0] - 0.3).abs() < 1e-9, "{w:?}");
        }
    }

    #[test]
    fn edge_failover_victim_is_an_edge_large_index() {
        // paper_default cycles Small/Medium/Large by index: i % 3 == 2 is
        // EdgeLarge, whatever the edge count.
        for n in [3usize, 4, 7, 9] {
            let s = Script::builtin("edge-failover", 60_000.0, n).unwrap();
            let down = s
                .events
                .iter()
                .find_map(|e| match e.kind {
                    EventKind::ServerDown { server } => Some(server),
                    _ => None,
                })
                .unwrap();
            assert!(down < n);
            assert_eq!(down % 3, 2, "n={n}: victim {down} must be EdgeLarge");
        }
        // Degenerate small worlds fall back to the last edge.
        let s = Script::builtin("edge-failover", 60_000.0, 2).unwrap();
        assert!(s.events.iter().any(|e| e.kind == EventKind::ServerDown { server: 1 }));
    }

    #[test]
    fn unknown_event_type_is_a_hard_error_with_offset() {
        let text = r#"{"name":"x","events":[{"at_ms":0,"type":"sever_down","server":1}]}"#;
        let err = Script::parse(text).unwrap_err().to_string();
        assert!(err.contains("unknown event type \"sever_down\""), "{err}");
        let off = text.find("\"sever_down\"").unwrap();
        assert!(err.contains(&format!("byte {off}")), "{err}");
    }

    #[test]
    fn unknown_event_field_is_a_hard_error_with_offset() {
        let text =
            r#"{"name":"x","events":[{"at_ms":0,"type":"load_burst","rate_multiplier":2,"durationms":5}]}"#;
        let err = Script::parse(text).unwrap_err().to_string();
        assert!(err.contains("unknown field \"durationms\""), "{err}");
        let off = text.find("\"durationms\"").unwrap();
        assert!(err.contains(&format!("byte {off}")), "{err}");
        // from_json (no source text) still rejects, just without a span.
        let j = Json::parse(text).unwrap();
        assert!(Script::from_json(&j).is_err());
    }

    #[test]
    fn malformed_json_reports_parser_offset() {
        let err = Script::parse(r#"{"name":"x","events":[{]}"#).unwrap_err().to_string();
        assert!(err.contains("byte"), "{err}");
    }

    #[test]
    fn every_builtin_survives_strict_round_trip() {
        for name in Script::builtin_names() {
            let s = Script::builtin(name, 60_000.0, 9).unwrap();
            let parsed = Script::parse(&s.to_json().pretty()).unwrap();
            assert_eq!(s, parsed, "{name}");
        }
    }

    #[test]
    fn placement_change_requires_boolean_add() {
        let missing = r#"{"name":"x","events":[{"at_ms":0,"type":"placement_change",
            "server":0,"service":0,"tier":0}]}"#;
        assert!(Script::from_json(&Json::parse(missing).unwrap()).is_err());
        let stringly = r#"{"name":"x","events":[{"at_ms":0,"type":"placement_change",
            "server":0,"service":0,"tier":0,"add":"false"}]}"#;
        assert!(Script::from_json(&Json::parse(stringly).unwrap()).is_err());
    }

    #[test]
    fn validate_rejects_self_mobility() {
        let s = Script::new(
            "bad-mobility",
            vec![ScriptedEvent {
                at_ms: 0.0,
                kind: EventKind::UserMobility { from_edge: 1, to_edge: 1, fraction: 0.5 },
            }],
        );
        assert!(s.validate(4, 3, 10, 4).is_err());
    }

    #[test]
    fn validate_rejects_out_of_range() {
        let s = Script::new(
            "bad",
            vec![ScriptedEvent { at_ms: 0.0, kind: EventKind::ServerDown { server: 7 } }],
        );
        assert!(s.validate(4, 3, 10, 4).is_err());
        let s = Script::new(
            "bad2",
            vec![ScriptedEvent {
                at_ms: 0.0,
                kind: EventKind::UserMobility { from_edge: 0, to_edge: 1, fraction: 1.5 },
            }],
        );
        assert!(s.validate(4, 3, 10, 4).is_err());
        let s = Script::new(
            "bad3",
            vec![ScriptedEvent {
                at_ms: f64::NAN,
                kind: EventKind::ServerUp { server: 0 },
            }],
        );
        assert!(s.validate(4, 3, 10, 4).is_err());
    }

    #[test]
    fn labels_are_stable_json_tags() {
        let s = sample();
        let j = s.to_json();
        let types: Vec<&str> = j
            .get("events")
            .as_arr()
            .unwrap()
            .iter()
            .map(|e| e.get("type").as_str().unwrap())
            .collect();
        assert_eq!(
            types,
            vec![
                "load_burst",
                "server_down",
                "bandwidth_drift",
                "user_mobility",
                "placement_change",
                "server_up"
            ]
        );
    }
}
