//! Parallel scenario sweep: fan one scenario out across seeds × policies
//! on `std::thread` workers (via [`crate::benchkit::parallel_map`]),
//! aggregate mean/CI summaries, and resample each run's per-frame time
//! series onto a common grid for satisfaction-vs-time figures.
//!
//! Determinism: job k for (policy p, seed index s) always runs the DES
//! with seed `base.seed + s`, results return in job order regardless of
//! thread scheduling, and aggregation walks that order — so the output is
//! independent of `threads`.

use crate::benchkit::parallel_map;
use crate::coordinator::scheduler_by_name;
use crate::metrics::Series;
use crate::sim::des::Des;
use crate::sim::{DesConfig, DesReport};
use crate::util::stats::Accumulator;

/// One scenario sweep: `policies × num_seeds` DES runs.
#[derive(Clone, Debug)]
pub struct SweepConfig {
    /// Base DES configuration, including the scenario script (if any).
    pub base: DesConfig,
    pub policies: Vec<String>,
    /// Seeds used: `base.seed`, `base.seed + 1`, …
    pub num_seeds: usize,
    pub threads: usize,
}

impl Default for SweepConfig {
    fn default() -> Self {
        SweepConfig {
            base: DesConfig::default(),
            policies: vec!["gus".into(), "local-all".into()],
            num_seeds: 8,
            threads: crate::sim::montecarlo::default_threads(),
        }
    }
}

/// Aggregated outcome for one policy across all seeds.
#[derive(Clone, Debug, Default)]
pub struct PolicySweep {
    pub policy: String,
    pub satisfied_pct: Accumulator,
    pub served_pct: Accumulator,
    /// Scheduler drops + queue rejections, as % of generated.
    pub drop_pct: Accumulator,
    pub mean_completion_ms: Accumulator,
    /// Raw per-seed reports, in seed order (for time-series work).
    pub reports: Vec<DesReport>,
}

/// Run the sweep. Panics on an unknown policy name (callers validate via
/// [`scheduler_by_name`] first — the CLI does).
pub fn run_sweep(cfg: &SweepConfig) -> Vec<PolicySweep> {
    assert!(cfg.num_seeds > 0, "sweep needs at least one seed");
    // Policy-major job list → aggregation below is a straight walk.
    let jobs: Vec<(usize, u64)> = (0..cfg.policies.len())
        .flat_map(|pi| (0..cfg.num_seeds).map(move |s| (pi, cfg.base.seed + s as u64)))
        .collect();
    let reports = parallel_map(&jobs, cfg.threads, |_, &(pi, seed)| {
        let policy =
            scheduler_by_name(&cfg.policies[pi]).expect("unknown policy in scenario sweep"); // lint:allow(unwrap) — policy names validated at config load
        let mut run_cfg = cfg.base.clone();
        run_cfg.seed = seed;
        Des::new(run_cfg, policy.as_ref()).run()
    });
    let mut out = Vec::with_capacity(cfg.policies.len());
    let mut it = reports.into_iter();
    for policy in &cfg.policies {
        let mut agg = PolicySweep { policy: policy.clone(), ..Default::default() };
        for _ in 0..cfg.num_seeds {
            let r = it.next().expect("one report per job"); // lint:allow(unwrap) — jobs list is policy-major by construction
            let n = r.generated.max(1) as f64;
            agg.satisfied_pct.push(r.satisfied_pct());
            agg.served_pct.push(100.0 * r.served as f64 / n);
            agg.drop_pct.push(100.0 * (r.dropped + r.rejected_at_queue) as f64 / n);
            if r.completion.count() > 0 {
                agg.mean_completion_ms.push(r.completion.mean());
            }
            agg.reports.push(r);
        }
        out.push(agg);
    }
    out
}

/// Resample one report's per-frame series onto the regular grid
/// `frame_ms, 2·frame_ms, …` up to `horizon_ms`: each grid point carries
/// the satisfaction (% of requests *generated* in that window that ended
/// satisfied, capped at 100 — completions lag arrivals by up to a
/// deadline, so this is a windowed approximation). Windows with no
/// arrivals carry the previous value forward; windows *before the first
/// arrival* are NaN rather than a fabricated value, and the seed
/// aggregation in [`timeline_series`] skips them.
pub fn timeline_on_grid(report: &DesReport, frame_ms: f64, horizon_ms: f64) -> Vec<f64> {
    assert!(frame_ms > 0.0 && horizon_ms > 0.0);
    let n = (horizon_ms / frame_ms).ceil() as usize;
    let mut out = Vec::with_capacity(n);
    let (mut prev_gen, mut prev_sat) = (0u64, 0u64);
    let (mut cur_gen, mut cur_sat) = (0u64, 0u64);
    let mut fi = 0usize;
    let mut last_val = f64::NAN;
    for k in 0..n {
        let t = (k as f64 + 1.0) * frame_ms;
        while fi < report.frames.len() && report.frames[fi].t_ms <= t + 1e-9 {
            cur_gen = report.frames[fi].generated;
            cur_sat = report.frames[fi].satisfied;
            fi += 1;
        }
        let dg = cur_gen.saturating_sub(prev_gen);
        let ds = cur_sat.saturating_sub(prev_sat);
        if dg > 0 {
            last_val = (100.0 * ds as f64 / dg as f64).min(100.0);
        }
        out.push(last_val);
        prev_gen = cur_gen;
        prev_sat = cur_sat;
    }
    out
}

/// Build the satisfaction-vs-time [`Series`] (mean ± 95% CI over seeds,
/// one column per policy) from a finished sweep.
pub fn timeline_series(cfg: &SweepConfig, sweeps: &[PolicySweep]) -> Series {
    let frame = cfg.base.frame_ms;
    let horizon = cfg.base.horizon_ms;
    let n = (horizon / frame).ceil() as usize;
    let xs: Vec<f64> = (0..n).map(|k| (k as f64 + 1.0) * frame / 1e3).collect();
    let mut series = Series::new("time (s)", "windowed satisfaction (%)", xs);
    for sw in sweeps {
        let mut accs: Vec<Accumulator> = (0..n).map(|_| Accumulator::new()).collect();
        for report in &sw.reports {
            for (k, v) in timeline_on_grid(report, frame, horizon).iter().enumerate() {
                // NaN marks pre-first-arrival windows: no data, not 100%.
                if v.is_finite() {
                    accs[k].push(*v);
                }
            }
        }
        series.push_policy(
            &sw.policy,
            accs.iter().map(|a| a.mean()).collect(),
            accs.iter().map(|a| a.ci95()).collect(),
        );
    }
    series
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::service::CatalogParams;
    use crate::model::topology::TopologyParams;
    use crate::scenario::Script;
    use crate::sim::des::FrameSample;
    use crate::workload::{ScenarioParams, WorkloadParams};

    fn quick_base() -> DesConfig {
        DesConfig {
            scenario: ScenarioParams {
                topology: TopologyParams { num_edge: 3, num_cloud: 1, ..Default::default() },
                catalog: CatalogParams { num_services: 8, num_tiers: 3, ..Default::default() },
                workload: WorkloadParams {
                    deadline_mean_ms: 4000.0,
                    deadline_std_ms: 1500.0,
                    ..Default::default()
                },
            },
            horizon_ms: 24_000.0,
            arrival_rate_per_s: 4.0,
            ..Default::default()
        }
    }

    #[test]
    fn sweep_shapes_and_policy_order() {
        let cfg = SweepConfig {
            base: quick_base(),
            policies: vec!["gus".into(), "local-all".into()],
            num_seeds: 3,
            threads: 2,
        };
        let sweeps = run_sweep(&cfg);
        assert_eq!(sweeps.len(), 2);
        assert_eq!(sweeps[0].policy, "gus");
        assert_eq!(sweeps[1].policy, "local-all");
        for sw in &sweeps {
            assert_eq!(sw.reports.len(), 3);
            assert_eq!(sw.satisfied_pct.count(), 3);
            for r in &sw.reports {
                assert!(r.generated > 0);
                assert!(!r.frames.is_empty(), "per-frame series must be recorded");
            }
        }
    }

    #[test]
    fn sweep_is_thread_count_independent() {
        let mut base = quick_base();
        base.script = Script::builtin("flash-crowd", base.horizon_ms, 3);
        let mk = |threads| SweepConfig {
            base: base.clone(),
            policies: vec!["gus".into()],
            num_seeds: 4,
            threads,
        };
        let a = run_sweep(&mk(1));
        let b = run_sweep(&mk(8));
        assert_eq!(a[0].satisfied_pct.mean(), b[0].satisfied_pct.mean());
        for (x, y) in a[0].reports.iter().zip(b[0].reports.iter()) {
            assert_eq!(x.to_json().dump(), y.to_json().dump(), "reports must be identical");
        }
    }

    #[test]
    fn timeline_grid_windows_cumulative_counters() {
        let mut r = DesReport::default();
        // Frames: 100 generated / 80 satisfied by t=3000; 200/120 by 6000.
        r.frames.push(FrameSample {
            t_ms: 3000.0,
            generated: 100,
            satisfied: 80,
            ..Default::default()
        });
        r.frames.push(FrameSample {
            t_ms: 6000.0,
            generated: 200,
            satisfied: 120,
            ..Default::default()
        });
        let tl = timeline_on_grid(&r, 3000.0, 9000.0);
        assert_eq!(tl.len(), 3);
        assert!((tl[0] - 80.0).abs() < 1e-9);
        assert!((tl[1] - 40.0).abs() < 1e-9);
        // Empty window carries the previous value.
        assert!((tl[2] - 40.0).abs() < 1e-9);
    }

    #[test]
    fn timeline_grid_marks_pre_arrival_windows_nan() {
        let mut r = DesReport::default();
        r.frames.push(FrameSample {
            t_ms: 6000.0,
            generated: 50,
            satisfied: 25,
            ..Default::default()
        });
        let tl = timeline_on_grid(&r, 3000.0, 9000.0);
        assert!(tl[0].is_nan(), "no data yet must not read as 100%");
        assert!((tl[1] - 50.0).abs() < 1e-9);
        assert!((tl[2] - 50.0).abs() < 1e-9, "empty later window carries forward");
    }

    #[test]
    fn timeline_series_has_one_column_per_policy() {
        let cfg = SweepConfig {
            base: quick_base(),
            policies: vec!["gus".into(), "random".into()],
            num_seeds: 2,
            threads: 2,
        };
        let sweeps = run_sweep(&cfg);
        let series = timeline_series(&cfg, &sweeps);
        assert_eq!(series.policies.len(), 2);
        let n = (cfg.base.horizon_ms / cfg.base.frame_ms).ceil() as usize;
        assert_eq!(series.xs.len(), n);
        for (_, ys, cis) in &series.policies {
            assert_eq!(ys.len(), n);
            assert_eq!(cis.len(), n);
            assert!(ys.iter().all(|y| (0.0..=100.0).contains(y)), "{ys:?}");
        }
    }
}
