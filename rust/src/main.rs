//! `edgeus` — CLI launcher for the MUS/GUS reproduction.
//!
//! ```text
//! edgeus figure  --id fig1a [--runs 500] [--seed 7] [--csv out.csv]
//! edgeus testbed [--loads 60,120,240] [--policies gus,random] [--scale 50]
//! edgeus serve   [--scheduler gus] [--requests 200] [--scale 50]
//! edgeus optimal-gap [--sizes 4,6,8,10] [--instances 20]
//! edgeus simulate [--config cfg.json]
//! edgeus scenario --name flash-crowd [--policies gus,local-all] [--seeds 8]
//! edgeus verify  world.json [--kind world|script|schedule] [--json]
//! edgeus info    [--artifacts artifacts]
//! ```

use anyhow::{Context, Result};
use edgeus::config::load_montecarlo;
use edgeus::figures::{run_numerical, NumericalConfig, NumericalFigure};
use edgeus::obs::{chrome_trace, prometheus, Recorder};
use edgeus::serving::{ServingConfig, ServingSystem, TestbedExperiment};
use edgeus::sim::MonteCarlo;
use edgeus::util::cli::Args;
use std::sync::Arc;

fn main() {
    let args = Args::from_env(true);
    let result = match args.subcommand.as_deref() {
        Some("figure") => cmd_figure(&args),
        Some("testbed") => cmd_testbed(&args),
        Some("serve") => cmd_serve(&args),
        Some("optimal-gap") => cmd_optimal_gap(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("des") => cmd_des(&args),
        Some("scenario") => cmd_scenario(&args),
        Some("trace") => cmd_trace(&args),
        Some("verify") => cmd_verify(&args),
        Some("info") => cmd_info(&args),
        Some(other) => {
            eprintln!("unknown subcommand: {other}");
            print_usage();
            std::process::exit(2);
        }
        None => {
            print_usage();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_usage() {
    eprintln!(
        "edgeus — Optimal Accuracy-Time Trade-off for DL Services at the Edge\n\
         subcommands:\n  \
         figure --id fig1a|fig1b|fig1c|fig1d [--runs N] [--seed S] [--csv PATH]\n  \
         testbed [--loads 60,120,240,360] [--policies gus,random,local-all,offload-all]\n          \
         [--scale 50] [--artifacts DIR]\n  \
         serve [--scheduler gus] [--requests N] [--scale 50] [--artifacts DIR]\n        \
         [--scenario NAME | --script FILE.json] [--synthetic] [--seed S]\n        \
         scenario scripts replay live (outages, bursts, drift, mobility, placement);\n        \
         --synthetic mocks inference (no artifacts needed); inputs gated via verify\n  \
         optimal-gap [--sizes 4,6,8,10] [--instances 20] [--seed S]\n  \
         simulate [--config cfg.json] [--runs N]\n  \
         des [--rates 1,4,16,64] [--policies gus,local-all] [--horizon-s 60]\n  \
         scenario [--name flash-crowd|edge-failover|degraded-backhaul|commuter-wave]\n           \
         [--script FILE.json] [--policies gus,local-all] [--seeds 8] [--seed 7]\n           \
         [--rate 8] [--horizon-s 120] [--threads N] [--save FILE.json] [--csv PATH] [--list]\n  \
         trace [--out trace.json] [--rate 4] [--horizon-s 60] | [--stats FILE]\n  \
         verify FILE.json [--kind world|script|schedule] [--json] [--strict]\n          \
         [--horizon-s H] [--rate R] — static checks, exit 1 on errors\n  \
         info [--artifacts DIR]\n\
         observability (des, scenario, serve, testbed):\n  \
         [--trace-out T.json] [--metrics-out M.prom] [--trace-capacity 65536]\n  \
         --trace-out writes a Chrome trace-event file (chrome://tracing / Perfetto);\n  \
         --metrics-out writes Prometheus-style text; either flag enables the recorder."
    );
}

/// Build the recorder requested by `--trace-out` / `--metrics-out`;
/// `None` (recorder fully off) when neither flag is present.
fn obs_recorder(args: &Args) -> Option<Arc<Recorder>> {
    if args.get("trace-out").is_none() && args.get("metrics-out").is_none() {
        return None;
    }
    let capacity = args.get_usize("trace-capacity", 1 << 16);
    Some(Arc::new(Recorder::enabled(capacity)))
}

/// Write the exports the user asked for from a finished recorder.
fn write_obs_outputs(args: &Args, rec: &Recorder) -> Result<()> {
    if let Some(path) = args.get("trace-out") {
        std::fs::write(path, chrome_trace(rec).dump())?;
        eprintln!(
            "wrote {path} ({} trace events retained, {} overwritten)",
            rec.events().len(),
            rec.dropped_events()
        );
    }
    if let Some(path) = args.get("metrics-out") {
        std::fs::write(path, prometheus(rec))?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

/// Re-run one (rate, policy) DES point with the recorder attached and emit
/// the requested exports plus the per-frame decision-explanation table.
/// Sweeps stay uninstrumented so their aggregate numbers are untouched.
fn run_instrumented_des(
    args: &Args,
    base: &edgeus::sim::DesConfig,
    rate: f64,
    policy: &str,
) -> Result<()> {
    let Some(recorder) = obs_recorder(args) else { return Ok(()) };
    let scheduler = edgeus::coordinator::scheduler_by_name(policy)
        .with_context(|| format!("unknown policy {policy}"))?;
    let mut cfg = base.clone();
    cfg.arrival_rate_per_s = rate;
    eprintln!("instrumented DES pass: {policy} @ {rate} req/s");
    let report = edgeus::sim::Des::new(cfg, scheduler.as_ref())
        .with_recorder(&recorder)
        .run();
    println!(
        "\n# decision explanations — {policy} @ {rate} req/s\n\n{}",
        report.explain_markdown()
    );
    write_obs_outputs(args, &recorder)
}

/// Fail fast on inputs the static verifier rejects: every diagnostic is
/// printed to stderr (warnings/infos are advisory), and any error-level
/// finding aborts before simulation state is built — `des`, `scenario`,
/// and `serve` all fail with the same diagnostics as `edgeus verify`.
fn gate_diagnostics(what: &str, d: &edgeus::verify::Diagnostics) -> Result<()> {
    use edgeus::verify::Severity;
    if d.is_empty() {
        return Ok(());
    }
    eprint!("{}", d.render_text());
    if d.has_errors() {
        anyhow::bail!(
            "{what} failed verification with {} error(s) (see diagnostics above)",
            d.count(Severity::Error)
        );
    }
    Ok(())
}

fn cmd_verify(args: &Args) -> Result<()> {
    use edgeus::verify::{verify_file, DocKind, Severity, VerifyOptions};
    let path = args
        .positionals
        .first()
        .map(|s| s.as_str())
        .or_else(|| args.get("file"))
        .context("usage: edgeus verify <world|script|schedule>.json [--kind K] [--json] [--strict]")?;
    let kind = match args.get("kind") {
        Some(k) => {
            Some(DocKind::parse(k).with_context(|| format!("unknown --kind {k} (world|script|schedule)"))?)
        }
        None => None,
    };
    let opts = VerifyOptions {
        kind,
        horizon_ms: args.get("horizon-s").and_then(|s| s.parse::<f64>().ok()).map(|h| h * 1e3),
        arrival_rate_per_s: args.get("rate").and_then(|s| s.parse().ok()),
        shape: None,
    };
    let d = verify_file(path, &opts);
    if args.flag("json") {
        // Byte-stable: diagnostics are sorted and keys render in a fixed
        // order, so CI can diff this output meaningfully.
        println!("{}", d.to_json().pretty());
    } else if d.is_empty() {
        println!("{path}: OK (0 diagnostics)");
    } else {
        print!("{}", d.render_text());
        println!(
            "{path}: {} error(s), {} warning(s), {} info",
            d.count(Severity::Error),
            d.count(Severity::Warning),
            d.count(Severity::Info)
        );
    }
    if d.has_errors() || (args.flag("strict") && !d.is_empty()) {
        std::process::exit(1);
    }
    Ok(())
}

fn cmd_scenario(args: &Args) -> Result<()> {
    use edgeus::scenario::{run_sweep, timeline_series, Script, SweepConfig};
    if args.flag("list") {
        println!("built-in scenarios: {}", Script::builtin_names().join(", "));
        return Ok(());
    }
    let defaults = edgeus::sim::DesConfig::default();
    let seed = args.get_u64("seed", defaults.seed);
    let mut base = edgeus::sim::DesConfig {
        horizon_ms: args.get_f64("horizon-s", 120.0) * 1e3,
        arrival_rate_per_s: args.get_f64("rate", 8.0),
        seed,
        ..defaults
    };
    anyhow::ensure!(base.horizon_ms > 0.0, "--horizon-s must be positive");
    anyhow::ensure!(base.arrival_rate_per_s > 0.0, "--rate must be positive");
    let num_seeds = args.get_usize("seeds", 8);
    anyhow::ensure!(num_seeds > 0, "--seeds must be at least 1");
    let script = match args.get("script") {
        Some(path) => match Script::load(path) {
            Ok(s) => s,
            // A bad script path/file is a user-input problem, not an
            // internal error: one diagnostic line, non-zero exit.
            Err(e) => {
                use edgeus::verify::{Code, Diagnostics};
                let code = if std::path::Path::new(path).exists() {
                    Code::ParseError
                } else {
                    Code::FileUnreadable
                };
                let mut d = Diagnostics::new();
                d.push(code, path, format!("{e:#}"));
                eprint!("{}", d.render_text());
                std::process::exit(1);
            }
        },
        None => {
            let name = args.get_or("name", "flash-crowd");
            Script::builtin(name, base.horizon_ms, base.scenario.topology.num_edge)
                .with_context(|| format!("unknown scenario {name} (see --list)"))?
        }
    };
    if let Some(path) = args.get("save") {
        script.save(path)?;
        eprintln!("wrote {path}");
    }
    let policies = args
        .get_list("policies")
        .unwrap_or_else(|| vec!["gus".into(), "local-all".into()]);
    for p in &policies {
        anyhow::ensure!(
            edgeus::coordinator::scheduler_by_name(p).is_some(),
            "unknown policy {p}"
        );
    }
    base.script = Some(script.clone());
    gate_diagnostics("scenario config", &edgeus::verify::verify_des_config(&base, &[]))?;
    let cfg = SweepConfig {
        base,
        policies,
        num_seeds,
        threads: args.get_usize("threads", edgeus::sim::montecarlo::default_threads()),
    };
    eprintln!(
        "scenario '{}': {} events, {} policies x {} seeds on {} threads, {:.0}s horizon @ {} req/s",
        script.name,
        script.len(),
        cfg.policies.len(),
        cfg.num_seeds,
        cfg.threads,
        cfg.base.horizon_ms / 1e3,
        cfg.base.arrival_rate_per_s,
    );
    let sweeps = run_sweep(&cfg);
    println!("\n# scenario '{}' — {} seeds per policy\n", script.name, cfg.num_seeds);
    println!("| policy | satisfied % (±95% CI) | served % | dropped+rejected % | mean completion (ms) |");
    println!("|---|---|---|---|---|");
    for s in &sweeps {
        println!(
            "| {} | {:.2} ±{:.2} | {:.2} | {:.2} | {:.0} |",
            s.policy,
            s.satisfied_pct.mean(),
            s.satisfied_pct.ci95(),
            s.served_pct.mean(),
            s.drop_pct.mean(),
            s.mean_completion_ms.mean(),
        );
    }
    let series = timeline_series(&cfg, &sweeps);
    println!("\n# per-frame satisfaction (%) vs time\n\n{}", series.to_markdown());
    if let Some(path) = args.get("csv") {
        std::fs::write(path, series.to_csv())?;
        eprintln!("wrote {path}");
    }
    // Optional instrumented pass (first policy, scripted world events show
    // up as scenario markers in the trace).
    if let Some(policy) = cfg.policies.first() {
        run_instrumented_des(args, &cfg.base, cfg.base.arrival_rate_per_s, policy)?;
    }
    Ok(())
}

fn cmd_des(args: &Args) -> Result<()> {
    let rates: Vec<f64> = args
        .get_f64_list("rates")
        .unwrap_or_else(|| vec![1.0, 4.0, 16.0, 64.0, 150.0]);
    let policies = args
        .get_list("policies")
        .unwrap_or_else(|| vec!["gus".into(), "random".into(), "local-all".into(), "offload-all".into()]);
    let policy_refs: Vec<&str> = policies.iter().map(|s| s.as_str()).collect();
    let defaults = edgeus::sim::DesConfig::default();
    let base = edgeus::sim::DesConfig {
        horizon_ms: args.get_f64("horizon-s", 60.0) * 1e3,
        seed: args.get_u64("seed", defaults.seed),
        ..defaults
    };
    gate_diagnostics("des config", &edgeus::verify::verify_des_config(&base, &rates))?;
    eprintln!("discrete-event load sweep: rates {rates:?} req/s over {}s", base.horizon_ms / 1e3);
    let series = edgeus::sim::des::load_sweep(&base, &policy_refs, &rates);
    println!("\n# DES — satisfied users (%) vs offered load\n\n{}", series.to_markdown());
    if let Some(path) = args.get("csv") {
        std::fs::write(path, series.to_csv())?;
        eprintln!("wrote {path}");
    }
    // Optional instrumented pass at the first (rate, policy) point.
    if let (Some(&rate), Some(policy)) = (rates.first(), policies.first()) {
        run_instrumented_des(args, &base, rate, policy)?;
    }
    Ok(())
}

fn cmd_trace(args: &Args) -> Result<()> {
    use edgeus::workload::trace::Trace;
    if let Some(path) = args.get("stats") {
        let t = Trace::load(path)?;
        let horizon = t.records.last().map(|r| r.arrival_ms).unwrap_or(0.0);
        println!(
            "trace {path}: {} records over {:.1}s ({:.2} req/s)",
            t.len(),
            horizon / 1e3,
            t.len() as f64 / (horizon / 1e3).max(1e-9)
        );
        return Ok(());
    }
    let out = args.get_or("out", "trace.json");
    let mut rng = edgeus::util::rng::Rng::new(args.get_u64("seed", 7));
    let t = Trace::synthesize(
        &edgeus::workload::WorkloadParams::default(),
        args.get_usize("services", 100),
        args.get_usize("edges", 9),
        args.get_f64("horizon-s", 60.0) * 1e3,
        args.get_f64("rate", 4.0),
        &mut rng,
    );
    t.save(out)?;
    println!("wrote {} records to {out}", t.len());
    Ok(())
}

fn cmd_figure(args: &Args) -> Result<()> {
    let id = args.get("id").context("--id fig1a|fig1b|fig1c|fig1d required")?;
    let figure = NumericalFigure::parse(id).with_context(|| format!("unknown figure {id}"))?;
    let defaults = NumericalConfig::default();
    let cfg = NumericalConfig {
        runs: args.get_usize("runs", defaults.runs),
        seed: args.get_u64("seed", defaults.seed),
        threads: args.get_usize("threads", defaults.threads),
        ..defaults
    };
    eprintln!("running {} with {} Monte-Carlo runs per point...", figure.id(), cfg.runs);
    let series = run_numerical(figure, &cfg);
    println!("\n# {} — {}\n", figure.id(), series.y_label);
    println!("{}", series.to_markdown());
    if let Some(path) = args.get("csv") {
        std::fs::write(path, series.to_csv())?;
        eprintln!("wrote {path}");
    }
    if let Some(path) = args.get("json") {
        std::fs::write(path, series.to_json().pretty())?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

fn cmd_testbed(args: &Args) -> Result<()> {
    let mut exp = TestbedExperiment::default();
    if let Some(loads) = args.get_list("loads") {
        exp.loads = loads.iter().map(|s| s.parse().unwrap_or(100)).collect();
    }
    if let Some(policies) = args.get_list("policies") {
        exp.policies = policies;
    }
    exp.base.artifacts_dir = args.get_or("artifacts", "artifacts").to_string();
    exp.base.time_scale = args.get_f64("scale", exp.base.time_scale);
    exp.base.seed = args.get_u64("seed", exp.base.seed);
    let recorder = obs_recorder(args);
    exp.recorder = recorder.clone();
    eprintln!(
        "testbed sweep: loads {:?}, policies {:?} (time scale {}x)",
        exp.loads, exp.policies, exp.base.time_scale
    );
    let result = exp.run()?;
    if let Some(r) = &recorder {
        write_obs_outputs(args, r)?;
    }
    for (panel, series) in [
        ("fig1e — satisfied users (%)", &result.satisfied),
        ("fig1f — locally processed (%)", &result.local),
        ("fig1g — offloaded to cloud (%)", &result.cloud),
        ("fig1h — offloaded to peer edges (%)", &result.peer),
    ] {
        println!("\n# {panel}\n\n{}", series.to_markdown());
    }
    if let Some(path) = args.get("csv") {
        let mut out = String::new();
        for (name, s) in [
            ("fig1e", &result.satisfied),
            ("fig1f", &result.local),
            ("fig1g", &result.cloud),
            ("fig1h", &result.peer),
        ] {
            out.push_str(&format!("# {name}\n{}\n", s.to_csv()));
        }
        std::fs::write(path, out)?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    use edgeus::scenario::Script;
    let defaults = ServingConfig::default();
    let mut cfg = ServingConfig {
        artifacts_dir: args.get_or("artifacts", "artifacts").to_string(),
        scheduler: args.get_or("scheduler", "gus").to_string(),
        total_requests: args.get_usize("requests", defaults.total_requests),
        time_scale: args.get_f64("scale", defaults.time_scale),
        seed: args.get_u64("seed", defaults.seed),
        deadline_ms: args.get_f64("deadline-ms", defaults.deadline_ms),
        min_accuracy_pct: args.get_f64("min-accuracy", defaults.min_accuracy_pct),
        synthetic: args.flag("synthetic"),
        ..defaults
    };
    // Scenario replay against the live runtime: a built-in by name, or a
    // JSON script file. File scripts are verified as *text* so every
    // diagnostic is anchored to the event's byte offset in the file.
    let script_from_file = args.get("script").is_some();
    cfg.script = match (args.get("scenario"), args.get("script")) {
        (Some(_), Some(_)) => anyhow::bail!("--scenario and --script are mutually exclusive"),
        (Some(name), None) => Some(
            Script::builtin(name, cfg.window_ms, cfg.num_edge)
                .with_context(|| format!("unknown scenario {name} (see `edgeus scenario --list`)"))?,
        ),
        (None, Some(path)) => {
            use edgeus::verify::{Code, Diagnostics};
            let text = match std::fs::read_to_string(path) {
                Ok(t) => t,
                Err(e) => {
                    let mut d = Diagnostics::new();
                    d.push(Code::FileUnreadable, path, format!("{e:#}"));
                    eprint!("{}", d.render_text());
                    std::process::exit(1);
                }
            };
            // Tier bounds are manifest-dependent; ServingSystem::new
            // re-checks against the real ladder after loading it.
            let shape = edgeus::verify::WorldShape {
                num_servers: cfg.num_edge + 1,
                num_edges: cfg.num_edge,
                num_services: 1,
                num_tiers: usize::MAX,
            };
            let d = edgeus::verify::verify_script_text(
                &text,
                &shape,
                Some(cfg.window_ms + cfg.deadline_ms),
            );
            if !d.is_empty() {
                eprint!("{}", d.render_text());
            }
            if d.has_errors() {
                std::process::exit(1);
            }
            Some(Script::parse(&text).with_context(|| format!("parsing {path}"))?)
        }
        (None, None) => None,
    };
    // File scripts were already gated above with byte offsets; strip the
    // script from the config-level gate so diagnostics don't repeat.
    let gate_cfg =
        if script_from_file { ServingConfig { script: None, ..cfg.clone() } } else { cfg.clone() };
    gate_diagnostics("serving config", &edgeus::verify::verify_serving_config(&gate_cfg))?;
    eprintln!(
        "serving {} requests with {} (time scale {}x{}{})...",
        cfg.total_requests,
        cfg.scheduler,
        cfg.time_scale,
        if cfg.synthetic { ", synthetic inference" } else { "" },
        cfg.script
            .as_ref()
            .map(|s| format!(", scenario {} ({} events)", s.name, s.events.len()))
            .unwrap_or_default(),
    );
    let recorder = obs_recorder(args);
    let mut system = ServingSystem::new(cfg)?;
    if let Some(r) = &recorder {
        system = system.with_recorder(Arc::clone(r));
    }
    let metrics = system.run()?;
    println!("{}", metrics.summary_markdown());
    if !metrics.phases.is_empty() {
        println!("\n## scenario phases\n\n{}", metrics.phases_markdown());
    }
    if let Some(r) = &recorder {
        write_obs_outputs(args, r)?;
    }
    Ok(())
}

fn cmd_optimal_gap(args: &Args) -> Result<()> {
    let sizes: Vec<usize> = args
        .get_list("sizes")
        .unwrap_or_else(|| vec!["4".into(), "6".into(), "8".into(), "10".into()])
        .iter()
        .map(|s| s.parse().unwrap_or(6))
        .collect();
    let instances = args.get_usize("instances", 20);
    let seed = args.get_u64("seed", 7);
    eprintln!("optimal-gap: sizes {sizes:?}, {instances} instances each");
    let result = edgeus::figures::run_optimal_gap(&sizes, instances, seed);
    println!("\n# GUS vs optimal (B&B)\n\n{}", result.series.to_markdown());
    println!(
        "mean GUS/OPT ratio: {:.3} (paper reports ~0.90); exact solves: {:.0}%",
        result.mean_ratio,
        100.0 * result.exact_fraction
    );
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let mc: MonteCarlo = match args.get("config") {
        Some(path) => load_montecarlo(path)?,
        None => MonteCarlo::default(),
    };
    let mc = MonteCarlo {
        runs: args.get_usize("runs", mc.runs),
        base_seed: args.get_u64("seed", mc.base_seed),
        threads: args.get_usize("threads", mc.threads),
        scenario: mc.scenario,
    };
    eprintln!("simulating {} Monte-Carlo runs...", mc.runs);
    let stats = mc.run();
    println!("| policy | satisfied % | served % | objective | local/cloud/peer/drop % |");
    println!("|---|---|---|---|---|");
    for s in &stats {
        println!(
            "| {} | {:.2} ±{:.2} | {:.2} | {:.4} | {:.0}/{:.0}/{:.0}/{:.0} |",
            s.name,
            s.satisfied_pct.mean(),
            s.satisfied_pct.ci95(),
            s.served_pct.mean(),
            s.objective.mean(),
            s.mix_local.mean(),
            s.mix_cloud.mean(),
            s.mix_peer.mean(),
            s.mix_dropped.mean(),
        );
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let dir = args.get_or("artifacts", "artifacts");
    let manifest = edgeus::runtime::Manifest::load(dir)?;
    println!(
        "artifacts in {dir}: {} modules, tiers {:?}",
        manifest.artifacts.len(),
        manifest.tiers()
    );
    println!("| name | tier | batch | params | flops/image | accuracy % |");
    println!("|---|---|---|---|---|---|");
    for a in &manifest.artifacts {
        println!(
            "| {} | {} | {} | {} | {} | {:.1} |",
            a.name, a.tier, a.batch, a.params, a.flops_per_image, a.profile_accuracy_pct
        );
    }
    if args.flag("load") {
        let engine = edgeus::runtime::InferenceEngine::load(dir)?;
        println!("\nloaded on {}: {:?}", engine.platform(), engine.artifact_names());
    } else {
        println!("\n(pass --load to compile the artifacts on the PJRT client)");
    }
    Ok(())
}
