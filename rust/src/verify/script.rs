//! Static checks over scenario scripts: index bounds, event-time
//! sanity, and the stateful overlap rules (`server_down`/`server_up`
//! pairing) that `Script::validate` cannot see because they span
//! events. Pure — nothing here runs a simulation.

use crate::scenario::script::{allowed_event_fields, EventKind, LinkClass, Script, EVENT_TYPES};
use crate::util::json::Json;
use crate::verify::diag::{Code, Diagnostics};
use crate::verify::WorldShape;

/// Verify a parsed script against a world shape. `horizon_ms` (when
/// known, e.g. from `--horizon-s`) enables the beyond-horizon check.
pub fn verify_script(script: &Script, shape: &WorldShape, horizon_ms: Option<f64>) -> Diagnostics {
    let mut out = Diagnostics::new();
    if script.is_empty() {
        out.push(Code::EmptyScript, "events", "script contains no events");
        return out;
    }
    let ns = shape.num_servers;
    let ne = shape.num_edges;
    // Track which servers the script has taken down so far; events are
    // time-sorted by construction, so a linear walk sees them in the
    // order the engine will apply them.
    let mut down = vec![false; ns];
    for (i, ev) in script.events.iter().enumerate() {
        let at = format!("events[{i}]");
        if !ev.at_ms.is_finite() || ev.at_ms < 0.0 {
            out.push(Code::EventTime, &at, format!("non-finite or negative trigger time {}", ev.at_ms));
        } else if let Some(h) = horizon_ms {
            if ev.at_ms >= h {
                out.push(
                    Code::EventBeyondHorizon,
                    &at,
                    format!("trigger time {} ms is at or beyond the {h} ms horizon — the event never fires", ev.at_ms),
                );
            }
        }
        match &ev.kind {
            EventKind::LoadBurst { rate_multiplier, duration_ms } => {
                if !rate_multiplier.is_finite() || *rate_multiplier <= 0.0 {
                    out.push(Code::LoadBurst, &at, format!("rate multiplier {rate_multiplier} must be finite and > 0"));
                }
                if !duration_ms.is_finite() || *duration_ms < 0.0 {
                    out.push(Code::LoadBurst, &at, format!("duration {duration_ms} ms must be finite and >= 0"));
                }
            }
            EventKind::ServerDown { server } => {
                if *server >= ns {
                    out.push(Code::ServerIndex, &at, format!("server {server} out of range ({ns} servers)"));
                } else if down[*server] {
                    out.push(Code::DownWhileDown, &at, format!("server {server} is already down here"));
                } else {
                    down[*server] = true;
                }
            }
            EventKind::ServerUp { server } => {
                if *server >= ns {
                    out.push(Code::ServerIndex, &at, format!("server {server} out of range ({ns} servers)"));
                } else if !down[*server] {
                    out.push(Code::UpWhileUp, &at, format!("server {server} is not down here — unmatched server_up"));
                } else {
                    down[*server] = false;
                }
            }
            EventKind::BandwidthDrift { link, factor } => {
                if !factor.is_finite() || *factor <= 0.0 {
                    out.push(Code::BadParam, &at, format!("bandwidth drift factor {factor} must be finite and > 0"));
                }
                if let LinkClass::Pair { a, b } = link {
                    if *a >= ns || *b >= ns {
                        out.push(Code::LinkPair, &at, format!("link pair ({a}, {b}) out of range ({ns} servers)"));
                    } else if a == b {
                        out.push(Code::LinkPair, &at, format!("link pair ({a}, {b}) is a self link"));
                    }
                }
            }
            EventKind::UserMobility { from_edge, to_edge, fraction } => {
                if *from_edge >= ne || *to_edge >= ne {
                    out.push(
                        Code::EdgeIndex,
                        &at,
                        format!("mobility edge {} out of range ({ne} edges)", (*from_edge).max(*to_edge)),
                    );
                } else if from_edge == to_edge {
                    out.push(Code::Mobility, &at, format!("from_edge == to_edge ({from_edge})"));
                }
                if !(0.0..=1.0).contains(fraction) {
                    out.push(Code::Mobility, &at, format!("fraction {fraction} not in [0, 1]"));
                }
            }
            EventKind::PlacementChange { server, service, tier, .. } => {
                if *server >= ns {
                    out.push(Code::ServerIndex, &at, format!("server {server} out of range ({ns} servers)"));
                }
                if *service >= shape.num_services {
                    out.push(
                        Code::ServiceIndex,
                        &at,
                        format!("service {service} not in the catalog ({} services)", shape.num_services),
                    );
                }
                if *tier >= shape.num_tiers {
                    out.push(Code::TierIndex, &at, format!("tier {tier} not in the catalog ({} tiers)", shape.num_tiers));
                }
            }
        }
    }
    for (server, is_down) in down.iter().enumerate() {
        if *is_down {
            out.push(
                Code::PermanentOutage,
                "events",
                format!("server {server} goes down and never comes back (no matching server_up)"),
            );
        }
    }
    out
}

/// Byte offset (into `text`) of each element of the top-level
/// `"events"` array — the anchors for `(byte N)`-located diagnostics
/// over script *files*. Walks the raw JSON with a string-aware bracket
/// scanner, so brackets inside strings don't confuse it. Returns an
/// empty vec when the array can't be found (offsets are then omitted
/// from diagnostics rather than guessed).
pub fn event_byte_offsets(text: &str) -> Vec<usize> {
    let bytes = text.as_bytes();
    let Some(key) = text.find("\"events\"") else {
        return Vec::new();
    };
    let mut i = key + "\"events\"".len();
    while i < bytes.len() && bytes[i] != b'[' {
        if !matches!(bytes[i], b' ' | b'\t' | b'\n' | b'\r' | b':') {
            return Vec::new(); // something unexpected between key and array
        }
        i += 1;
    }
    if i >= bytes.len() {
        return Vec::new();
    }
    let mut offsets = Vec::new();
    let mut depth = 0usize; // nesting depth counted from outside events[]
    let mut in_str = false;
    let mut escaped = false;
    let mut expecting_element = false;
    for (pos, &c) in bytes.iter().enumerate().skip(i) {
        if in_str {
            if escaped {
                escaped = false;
            } else if c == b'\\' {
                escaped = true;
            } else if c == b'"' {
                in_str = false;
            }
            continue;
        }
        match c {
            b'"' => {
                if depth == 1 && expecting_element {
                    offsets.push(pos);
                    expecting_element = false;
                }
                in_str = true;
            }
            b'[' | b'{' => {
                if depth == 1 && expecting_element {
                    offsets.push(pos);
                    expecting_element = false;
                }
                depth += 1;
                if depth == 1 {
                    expecting_element = true; // just entered events[]
                }
            }
            b']' | b'}' => {
                depth = depth.saturating_sub(1);
                if depth == 0 {
                    break; // closed the events array
                }
            }
            b',' => {
                if depth == 1 {
                    expecting_element = true;
                }
            }
            b' ' | b'\t' | b'\n' | b'\r' => {}
            _ => {
                if depth == 1 && expecting_element {
                    offsets.push(pos);
                    expecting_element = false;
                }
            }
        }
    }
    offsets
}

fn event_index(at: &str) -> Option<usize> {
    at.strip_prefix("events[")?.strip_suffix(']')?.parse().ok()
}

/// Verify a script *file's text*: parse strictly, run [`verify_script`],
/// and anchor every `events[i]`-located diagnostic to that element's
/// byte offset in the source — `events[2] (byte 187)` — so a rejected
/// `edgeus serve --script FILE.json` points into the offending file.
pub fn verify_script_text(
    text: &str,
    shape: &WorldShape,
    horizon_ms: Option<f64>,
) -> Diagnostics {
    let mut out = Diagnostics::new();
    let script = match Script::parse(text) {
        Ok(s) => s,
        Err(e) => {
            out.push(Code::ParseError, "events", format!("{e:#}"));
            return out;
        }
    };
    let offsets = event_byte_offsets(text);
    for d in verify_script(&script, shape, horizon_ms).sorted() {
        match event_index(&d.at).and_then(|i| offsets.get(i)) {
            Some(b) => out.push(d.code, format!("{} (byte {b})", d.at), d.message.clone()),
            None => out.push(d.code, &d.at, d.message.clone()),
        }
    }
    out
}

/// Verify a script *document* (already-parsed JSON). Structural issues
/// the strict parser would reject (unknown type/field, missing keys)
/// become diagnostics here instead of hard errors, so `edgeus verify`
/// reports everything it can in one pass.
pub fn verify_script_doc(j: &Json, shape: &WorldShape, horizon_ms: Option<f64>) -> Diagnostics {
    let mut out = Diagnostics::new();
    let Some(events) = j.get("events").as_arr() else {
        out.push(Code::ParseError, "events", "script has no events[] array");
        return out;
    };
    for (i, ev) in events.iter().enumerate() {
        let at = format!("events[{i}]");
        let Some(ty) = ev.get("type").as_str() else {
            out.push(Code::ParseError, &at, "event has no \"type\" string");
            continue;
        };
        let Some(allowed) = allowed_event_fields(ty) else {
            out.push(
                Code::UnknownEvent,
                &at,
                format!("unknown event type {ty:?} (expected one of {})", EVENT_TYPES.join(", ")),
            );
            continue;
        };
        if let Some(obj) = ev.as_obj() {
            for key in obj.keys() {
                if !allowed.contains(&key.as_str()) {
                    out.push(
                        Code::UnknownField,
                        &at,
                        format!("unknown field {key:?} for {ty} (allowed: {})", allowed.join(", ")),
                    );
                }
            }
        }
    }
    if out.has_errors() {
        return out;
    }
    match Script::from_json(j) {
        Ok(script) => out.extend(verify_script(&script, shape, horizon_ms)),
        Err(e) => out.push(Code::ParseError, "events", format!("{e:#}")),
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::script::ScriptedEvent;

    fn shape() -> WorldShape {
        WorldShape { num_servers: 4, num_edges: 3, num_services: 10, num_tiers: 4 }
    }

    fn ev(at_ms: f64, kind: EventKind) -> ScriptedEvent {
        ScriptedEvent { at_ms, kind }
    }

    #[test]
    fn builtin_scripts_are_clean() {
        for name in Script::builtin_names() {
            let s = Script::builtin(name, 120_000.0, 9).unwrap();
            let d = verify_script(
                &s,
                &WorldShape { num_servers: 10, num_edges: 9, num_services: 100, num_tiers: 10 },
                Some(120_000.0),
            );
            assert!(d.is_empty(), "{name}:\n{}", d.render_text());
        }
    }

    #[test]
    fn down_down_and_unmatched_up_are_flagged() {
        let s = Script::new(
            "x",
            vec![
                ev(1000.0, EventKind::ServerDown { server: 1 }),
                ev(2000.0, EventKind::ServerDown { server: 1 }),
                ev(3000.0, EventKind::ServerUp { server: 2 }),
            ],
        );
        let d = verify_script(&s, &shape(), None);
        assert!(d.has_code(Code::DownWhileDown));
        assert!(d.has_code(Code::UpWhileUp));
        assert!(d.has_code(Code::PermanentOutage));
    }

    #[test]
    fn matched_outage_is_clean() {
        let s = Script::new(
            "x",
            vec![
                ev(1000.0, EventKind::ServerDown { server: 1 }),
                ev(2000.0, EventKind::ServerUp { server: 1 }),
            ],
        );
        assert!(verify_script(&s, &shape(), Some(10_000.0)).is_empty());
    }

    #[test]
    fn beyond_horizon_is_a_warning_only() {
        let s = Script::new(
            "x",
            vec![ev(50_000.0, EventKind::LoadBurst { rate_multiplier: 2.0, duration_ms: 100.0 })],
        );
        let d = verify_script(&s, &shape(), Some(10_000.0));
        assert!(d.has_code(Code::EventBeyondHorizon));
        assert!(!d.has_errors());
        // Without a horizon the check cannot fire.
        assert!(verify_script(&s, &shape(), None).is_empty());
    }

    #[test]
    fn doc_level_unknowns_become_diagnostics() {
        let j = Json::parse(
            r#"{"name":"x","events":[
                {"at_ms":0,"type":"sever_down","server":1},
                {"at_ms":0,"type":"load_burst","rate_multiplier":2,"duration_ms":5,"extra":1}
            ]}"#,
        )
        .unwrap();
        let d = verify_script_doc(&j, &shape(), None);
        assert!(d.has_code(Code::UnknownEvent));
        assert!(d.has_code(Code::UnknownField));
    }

    #[test]
    fn empty_script_is_info() {
        let d = verify_script(&Script::new("x", vec![]), &shape(), None);
        assert!(d.has_code(Code::EmptyScript));
        assert!(!d.has_errors());
    }

    #[test]
    fn text_diagnostics_carry_byte_offsets() {
        let text = r#"{"name":"oob","events":[
            {"at_ms": 1000, "type": "server_down", "server": 1},
            {"at_ms": 2000, "type": "server_down", "server": 9},
            {"at_ms": 3000, "type": "server_up", "server": 1}
        ]}"#;
        let offs = event_byte_offsets(text);
        assert_eq!(offs.len(), 3);
        for &o in &offs {
            assert_eq!(text.as_bytes()[o], b'{');
        }
        assert!(text[offs[1]..].starts_with(r#"{"at_ms": 2000"#));
        // Server 9 doesn't exist in a 4-server shape: the E001 must be
        // anchored to event 1's byte offset in the source text.
        let d = verify_script_text(text, &shape(), None);
        assert!(d.has_code(Code::ServerIndex));
        let rendered = d.render_text();
        let want = format!("events[1] (byte {})", offs[1]);
        assert!(rendered.contains(&want), "{rendered}");
    }

    #[test]
    fn byte_offsets_survive_strings_with_brackets() {
        let text = r#"{"name":"tricky ] } [","events":[{"at_ms":0,"type":"load_burst","rate_multiplier":2.0,"duration_ms":5.0}]}"#;
        let offs = event_byte_offsets(text);
        assert_eq!(offs.len(), 1);
        assert!(text[offs[0]..].starts_with(r#"{"at_ms":0"#));
    }

    #[test]
    fn unparseable_text_is_a_parse_error_diagnostic() {
        let d = verify_script_text("{nope", &shape(), None);
        assert!(d.has_code(Code::ParseError));
        assert!(d.has_errors());
    }
}
