//! `edgeus verify` — the pure, run-nothing static checker over worlds,
//! scenario scripts, and serialized schedules (DESIGN.md
//! §Static-Analysis).
//!
//! Every check emits structured [`Diagnostic`]s with stable codes
//! (`E001`…, `W101`…, `I201`…) instead of bailing on the first problem,
//! so one pass reports everything wrong with an input. The same checks
//! run automatically at the top of `edgeus des`, `edgeus scenario`, and
//! `edgeus serve`, so every entry point fails fast with identical
//! diagnostics before any simulation state is built.
//!
//! Document kinds are sniffed from the top-level keys:
//! `events[]` → script, `assignments[]` → schedule, anything else →
//! world (the `config::scenario_from_json` format).

pub mod diag;
pub mod schedule;
pub mod script;
pub mod world;

pub use diag::{Code, Diagnostic, Diagnostics, Severity};
pub use schedule::verify_schedule_doc;
pub use script::{event_byte_offsets, verify_script, verify_script_doc, verify_script_text};
pub use world::{verify_scenario, DesLoad};

use crate::serving::ServingConfig;
use crate::sim::DesConfig;
use crate::util::json::Json;
use crate::workload::ScenarioParams;

/// The world dimensions a script is checked against.
#[derive(Clone, Copy, Debug)]
pub struct WorldShape {
    pub num_servers: usize,
    pub num_edges: usize,
    pub num_services: usize,
    pub num_tiers: usize,
}

impl WorldShape {
    pub fn of(s: &ScenarioParams) -> WorldShape {
        WorldShape {
            num_servers: s.topology.num_edge + s.topology.num_cloud,
            num_edges: s.topology.num_edge,
            num_services: s.catalog.num_services,
            num_tiers: s.catalog.num_tiers,
        }
    }
}

/// What a JSON document claims to be.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DocKind {
    World,
    Script,
    Schedule,
}

impl DocKind {
    pub fn parse(s: &str) -> Option<DocKind> {
        match s {
            "world" => Some(DocKind::World),
            "script" => Some(DocKind::Script),
            "schedule" => Some(DocKind::Schedule),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            DocKind::World => "world",
            DocKind::Script => "script",
            DocKind::Schedule => "schedule",
        }
    }
}

/// Sniff the document kind from its top-level keys.
pub fn sniff_kind(j: &Json) -> DocKind {
    if !j.get("events").is_null() {
        DocKind::Script
    } else if !j.get("assignments").is_null() {
        DocKind::Schedule
    } else {
        DocKind::World
    }
}

/// Options for file-level verification (CLI flags / caller context).
#[derive(Clone, Copy, Debug, Default)]
pub struct VerifyOptions {
    /// Force the document kind instead of sniffing.
    pub kind: Option<DocKind>,
    /// Run horizon, for the beyond-horizon and load screens.
    pub horizon_ms: Option<f64>,
    /// Offered arrival rate (req/s), for the capacity screen.
    pub arrival_rate_per_s: Option<f64>,
    /// Script world shape override (defaults to the paper world, or the
    /// world embedded in the document for world docs).
    pub shape: Option<WorldShape>,
}

/// Verify one parsed document.
pub fn verify_document(j: &Json, opts: &VerifyOptions) -> Diagnostics {
    let kind = opts.kind.unwrap_or_else(|| sniff_kind(j));
    match kind {
        DocKind::Script => {
            let shape = opts.shape.unwrap_or_else(|| WorldShape::of(&ScenarioParams::default()));
            verify_script_doc(j, &shape, opts.horizon_ms)
        }
        DocKind::Schedule => verify_schedule_doc(j),
        DocKind::World => {
            let scenario = crate::config::scenario_from_json(j);
            // A world file may embed its offered load under "des"; CLI
            // flags take precedence over the embedded values.
            let des = j.get("des");
            let defaults = DesConfig::default();
            let rate = opts
                .arrival_rate_per_s
                .or_else(|| des.get("arrival_rate_per_s").as_f64());
            let load = rate.map(|r| DesLoad {
                arrival_rate_per_s: r,
                frame_ms: des.get("frame_ms").as_f64().unwrap_or(defaults.frame_ms),
                horizon_ms: opts
                    .horizon_ms
                    .or_else(|| des.get("horizon_ms").as_f64())
                    .unwrap_or(defaults.horizon_ms),
            });
            verify_scenario(&scenario, load.as_ref())
        }
    }
}

/// Verify a document file on disk: unreadable files and malformed JSON
/// become diagnostics (`E019`/`E020`), never panics or bare errors.
pub fn verify_file(path: &str, opts: &VerifyOptions) -> Diagnostics {
    let mut out = Diagnostics::new();
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            out.push(Code::FileUnreadable, path, format!("{e}"));
            return out;
        }
    };
    let j = match Json::parse(&text) {
        Ok(j) => j,
        Err(e) => {
            out.push(Code::ParseError, path, format!("{e}"));
            return out;
        }
    };
    verify_document(&j, opts)
}

/// The auto-check at the top of `edgeus des` and `edgeus scenario`:
/// world parameters plus the attached script (if any) against the
/// configured load, all as one diagnostic list.
pub fn verify_des_config(cfg: &DesConfig, rates_per_s: &[f64]) -> Diagnostics {
    let max_rate = rates_per_s.iter().cloned().fold(cfg.arrival_rate_per_s, f64::max);
    let load = DesLoad {
        arrival_rate_per_s: max_rate,
        frame_ms: cfg.frame_ms,
        horizon_ms: cfg.horizon_ms,
    };
    let mut out = verify_scenario(&cfg.scenario, Some(&load));
    if let Some(script) = &cfg.script {
        out.extend(verify_script(script, &WorldShape::of(&cfg.scenario), Some(cfg.horizon_ms)));
    }
    out
}

/// The auto-check at the top of `edgeus serve`: the testbed analogue of
/// the world checks (the serving config carries its world inline).
pub fn verify_serving_config(cfg: &ServingConfig) -> Diagnostics {
    let mut out = Diagnostics::new();
    if cfg.num_edge == 0 {
        out.push(Code::NoEdges, "serving", "no edge servers configured — users cannot be covered");
    }
    for (name, v) in [
        ("total_requests", cfg.total_requests as f64),
        ("window_ms", cfg.window_ms),
        ("frame_ms", cfg.frame_ms),
        ("queue_capacity", cfg.queue_capacity as f64),
        ("time_scale", cfg.time_scale),
        ("deadline_ms", cfg.deadline_ms),
        ("edge_proc_base_ms", cfg.edge_proc_base_ms),
        ("cloud_proc_base_ms", cfg.cloud_proc_base_ms),
        ("tier_slowdown", cfg.tier_slowdown),
    ] {
        if !v.is_finite() || v <= 0.0 {
            out.push(Code::BadParam, "serving", format!("{name} must be finite and > 0 (got {v})"));
        }
    }
    if !(0.0..=100.0).contains(&cfg.min_accuracy_pct) {
        out.push(
            Code::BadParam,
            "serving",
            format!("min_accuracy_pct {} must be in [0, 100]", cfg.min_accuracy_pct),
        );
    }
    if cfg.gamma_edge == 0 {
        out.push(
            Code::ZeroGamma,
            "serving",
            "gamma_edge = 0: edges have no executor workers — every local candidate is infeasible",
        );
    }
    if out.has_errors() {
        return out;
    }
    let fastest = cfg.edge_proc_base_ms.min(cfg.cloud_proc_base_ms);
    if cfg.deadline_ms < fastest {
        out.push(
            Code::DeadlineInfeasible,
            "serving",
            format!(
                "deadline {} ms is below the fastest tier's processing time {} ms — no request can be satisfied",
                cfg.deadline_ms, fastest
            ),
        );
    }
    if let Some(script) = &cfg.script {
        // Shape of the serving world: num_edge edges + one cloud, one
        // service. Tier count comes from the manifest, unknown at config
        // level — usize::MAX disables the tier-bound check here;
        // `ServingSystem::new` re-verifies against the real ladder.
        let shape = WorldShape {
            num_servers: cfg.num_edge + 1,
            num_edges: cfg.num_edge,
            num_services: 1,
            num_tiers: usize::MAX,
        };
        out.extend(verify_script(script, &shape, Some(cfg.window_ms + cfg.deadline_ms)));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sniffing_matches_document_shape() {
        let w = Json::parse(r#"{"topology":{"num_edge":3}}"#).unwrap();
        let s = Json::parse(r#"{"name":"x","events":[]}"#).unwrap();
        let c = Json::parse(r#"{"gamma":[1],"assignments":[]}"#).unwrap();
        assert_eq!(sniff_kind(&w), DocKind::World);
        assert_eq!(sniff_kind(&s), DocKind::Script);
        assert_eq!(sniff_kind(&c), DocKind::Schedule);
    }

    #[test]
    fn default_des_config_is_clean() {
        let d = verify_des_config(&DesConfig::default(), &[]);
        assert!(d.is_empty(), "{}", d.render_text());
    }

    #[test]
    fn des_config_with_builtin_scripts_is_clean() {
        use crate::scenario::Script;
        for name in Script::builtin_names() {
            let defaults = DesConfig::default();
            let cfg = DesConfig {
                script: Script::builtin(name, defaults.horizon_ms, defaults.scenario.topology.num_edge),
                ..defaults
            };
            let d = verify_des_config(&cfg, &[]);
            assert!(d.is_empty(), "{name}:\n{}", d.render_text());
        }
    }

    #[test]
    fn default_serving_config_is_clean() {
        let d = verify_serving_config(&ServingConfig::default());
        assert!(d.is_empty(), "{}", d.render_text());
    }

    #[test]
    fn serving_config_catches_bad_qos() {
        let cfg = ServingConfig { min_accuracy_pct: 130.0, ..ServingConfig::default() };
        assert!(verify_serving_config(&cfg).has_code(Code::BadParam));
        let cfg = ServingConfig { deadline_ms: 10.0, ..ServingConfig::default() };
        assert!(verify_serving_config(&cfg).has_code(Code::DeadlineInfeasible));
    }

    #[test]
    fn serving_config_gates_attached_scripts() {
        use crate::scenario::{EventKind, Script, ScriptedEvent};
        // Server 5 is valid in the paper's 10-server world but not in
        // the default 3-server serving world (2 edges + cloud).
        let script = Script::new(
            "oob",
            vec![ScriptedEvent { at_ms: 1_000.0, kind: EventKind::ServerDown { server: 5 } }],
        );
        let cfg = ServingConfig { script: Some(script), ..ServingConfig::default() };
        let d = verify_serving_config(&cfg);
        assert!(d.has_code(Code::ServerIndex), "{}", d.render_text());
        assert!(d.has_errors());
        // A builtin sized for the serving world passes the same gate.
        let script = Script::builtin("edge-failover", 60_000.0, 2).unwrap();
        let cfg = ServingConfig { script: Some(script), ..ServingConfig::default() };
        let d = verify_serving_config(&cfg);
        assert!(!d.has_errors(), "{}", d.render_text());
    }

    #[test]
    fn missing_file_and_bad_json_become_diagnostics() {
        let opts = VerifyOptions::default();
        let d = verify_file("/nonexistent/edgeus-no-such.json", &opts);
        assert!(d.has_code(Code::FileUnreadable));
        let dir = std::env::temp_dir().join("edgeus_verify_mod_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.json");
        std::fs::write(&p, "{nope").unwrap();
        let d = verify_file(p.to_str().unwrap(), &opts);
        assert!(d.has_code(Code::ParseError));
    }

    #[test]
    fn world_doc_embedded_load_drives_capacity_screen() {
        let j = Json::parse(r#"{"des":{"arrival_rate_per_s":500,"frame_ms":3000,"horizon_ms":60000}}"#)
            .unwrap();
        let d = verify_document(&j, &VerifyOptions::default());
        assert!(d.has_code(Code::DemandExceedsCapacity), "{}", d.render_text());
    }
}
