//! Schedule-level lint over *serialized* schedules: a JSON document
//! describing per-server capacities and a list of request→(server,
//! service, tier) assignments, checked without constructing a problem
//! instance. (In-process schedules are checked by
//! `coordinator::us::validate_schedule` against a live instance; this
//! is the offline analogue for schedules exchanged as files.)
//!
//! Document format:
//!
//! ```json
//! {
//!   "gamma": [2, 3, 4, 24],
//!   "down": [1],
//!   "num_services": 100,
//!   "num_tiers": 10,
//!   "assignments": [
//!     {"request": 0, "server": 0, "service": 5, "tier": 2, "comp_cost": 1.0}
//!   ]
//! }
//! ```
//!
//! `down`, `num_services`, `num_tiers`, and `comp_cost` (default 1.0)
//! are optional; `gamma` and `assignments` are required.

use crate::util::json::Json;
use crate::verify::diag::{Code, Diagnostics};

pub fn verify_schedule_doc(j: &Json) -> Diagnostics {
    let mut out = Diagnostics::new();
    let Some(gamma) = j.get("gamma").as_arr() else {
        out.push(Code::ParseError, "gamma", "schedule needs a gamma[] capacity array (one entry per server)");
        return out;
    };
    let Some(assignments) = j.get("assignments").as_arr() else {
        out.push(Code::ParseError, "assignments", "schedule needs an assignments[] array");
        return out;
    };
    let num_servers = gamma.len();
    let gamma: Vec<f64> = gamma.iter().map(|g| g.as_f64().unwrap_or(f64::NAN)).collect();
    for (jx, g) in gamma.iter().enumerate() {
        if !g.is_finite() || *g < 0.0 {
            out.push(Code::BadParam, format!("gamma[{jx}]"), format!("capacity must be finite and >= 0 (got {g})"));
        }
    }
    let mut down = vec![false; num_servers];
    if let Some(d) = j.get("down").as_arr() {
        for (i, idx) in d.iter().enumerate() {
            match idx.as_usize() {
                Some(s) if s < num_servers => down[s] = true,
                Some(s) => out.push(
                    Code::ServerIndex,
                    format!("down[{i}]"),
                    format!("server {s} out of range ({num_servers} servers)"),
                ),
                None => out.push(Code::ParseError, format!("down[{i}]"), "down entries must be server indices"),
            }
        }
    }
    for (jx, (g, d)) in gamma.iter().zip(down.iter()).enumerate() {
        if *g == 0.0 && !d {
            out.push(
                Code::ZeroGamma,
                format!("gamma[{jx}]"),
                format!("server {jx} is up with zero γ — placements there can never serve"),
            );
        }
    }
    let num_services = j.get("num_services").as_usize();
    let num_tiers = j.get("num_tiers").as_usize();

    let mut assigned: Vec<Option<usize>> = Vec::new(); // request -> first assignment index
    let mut used = vec![0.0f64; num_servers];
    for (i, a) in assignments.iter().enumerate() {
        let at = format!("assignments[{i}]");
        let Some(request) = a.get("request").as_usize() else {
            out.push(Code::ParseError, &at, "assignment needs a \"request\" index");
            continue;
        };
        if assigned.len() <= request {
            assigned.resize(request + 1, None);
        }
        match assigned[request] {
            Some(first) => {
                out.push(
                    Code::DuplicateAssignment,
                    &at,
                    format!("request {request} already assigned at assignments[{first}]"),
                );
                continue;
            }
            None => assigned[request] = Some(i),
        }
        let Some(server) = a.get("server").as_usize() else {
            out.push(Code::ParseError, &at, "assignment needs a \"server\" index");
            continue;
        };
        if server >= num_servers {
            out.push(Code::ServerIndex, &at, format!("server {server} out of range ({num_servers} servers)"));
            continue;
        }
        if down[server] {
            out.push(Code::DownServerAssignment, &at, format!("request {request} assigned to down server {server}"));
        }
        if let (Some(ns), Some(k)) = (num_services, a.get("service").as_usize()) {
            if k >= ns {
                out.push(Code::ServiceIndex, &at, format!("service {k} not in the catalog ({ns} services)"));
            }
        }
        if let (Some(nt), Some(l)) = (num_tiers, a.get("tier").as_usize()) {
            if l >= nt {
                out.push(Code::TierIndex, &at, format!("tier {l} not in the catalog ({nt} tiers)"));
            }
        }
        used[server] += a.get("comp_cost").as_f64().unwrap_or(1.0);
    }
    for (jx, (u, g)) in used.iter().zip(gamma.iter()).enumerate() {
        if g.is_finite() && *u > g + 1e-9 {
            out.push(
                Code::GammaOverflow,
                format!("gamma[{jx}]"),
                format!("server {jx}: assigned computation cost {u:.3} exceeds γ = {g:.3}"),
            );
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn doc(text: &str) -> Diagnostics {
        verify_schedule_doc(&Json::parse(text).unwrap())
    }

    #[test]
    fn clean_schedule_passes() {
        let d = doc(
            r#"{"gamma":[2,3],"num_services":4,"num_tiers":3,"assignments":[
                {"request":0,"server":0,"service":1,"tier":2},
                {"request":1,"server":1,"service":0,"tier":0,"comp_cost":1.5}
            ]}"#,
        );
        assert!(d.is_empty(), "{}", d.render_text());
    }

    #[test]
    fn duplicate_assignment_flagged() {
        let d = doc(
            r#"{"gamma":[2],"assignments":[
                {"request":0,"server":0},{"request":0,"server":0}
            ]}"#,
        );
        assert!(d.has_code(Code::DuplicateAssignment));
    }

    #[test]
    fn down_server_and_overflow_flagged() {
        let d = doc(
            r#"{"gamma":[1,2],"down":[1],"assignments":[
                {"request":0,"server":1},
                {"request":1,"server":0},{"request":2,"server":0}
            ]}"#,
        );
        assert!(d.has_code(Code::DownServerAssignment));
        assert!(d.has_code(Code::GammaOverflow), "{}", d.render_text());
    }

    #[test]
    fn zero_gamma_up_server_warns() {
        let d = doc(r#"{"gamma":[0,2],"assignments":[]}"#);
        assert!(d.has_code(Code::ZeroGamma));
        assert!(!d.has_errors());
        // A *down* zero-γ server is fine — the outage explains it.
        let d = doc(r#"{"gamma":[0,2],"down":[0],"assignments":[]}"#);
        assert!(d.is_empty());
    }

    #[test]
    fn missing_gamma_is_a_parse_error() {
        assert!(doc(r#"{"assignments":[]}"#).has_code(Code::ParseError));
    }

    #[test]
    fn out_of_range_indices_flagged() {
        let d = doc(
            r#"{"gamma":[2],"num_services":3,"num_tiers":2,"assignments":[
                {"request":0,"server":5},
                {"request":1,"server":0,"service":9,"tier":7}
            ]}"#,
        );
        assert!(d.has_code(Code::ServerIndex));
        assert!(d.has_code(Code::ServiceIndex));
        assert!(d.has_code(Code::TierIndex));
    }
}
