//! The diagnostic substrate shared by every `edgeus verify` checker:
//! stable codes, fixed severities, and byte-stable rendering (sorted
//! text and JSON) so CI diffs of verifier output are meaningful.
//!
//! The code table is documented in DESIGN.md §Static-Analysis; every
//! code has exactly one minimal failing fixture under
//! `rust/tests/fixtures/verify/` (enforced by `tests/verify_cli.rs`).

use crate::util::json::Json;

/// Diagnostic severity, ordered most severe first.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Error,
    Warning,
    Info,
}

impl Severity {
    pub fn as_str(&self) -> &'static str {
        match self {
            Severity::Error => "error",
            Severity::Warning => "warning",
            Severity::Info => "info",
        }
    }
}

/// Every diagnostic the verifier can emit. Codes are append-only: once
/// published in DESIGN.md they never change meaning or severity.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Code {
    /// E001 — server index out of range.
    ServerIndex,
    /// E002 — edge index out of range (user mobility targets edges).
    EdgeIndex,
    /// E003 — service index out of range.
    ServiceIndex,
    /// E004 — tier index out of range.
    TierIndex,
    /// E005 — non-finite or negative event trigger time.
    EventTime,
    /// E006 — `server_down` on a server that is already down.
    DownWhileDown,
    /// E007 — `server_up` on a server that is not down.
    UpWhileUp,
    /// E008 — invalid bandwidth-drift link pair (self link or out of range).
    LinkPair,
    /// E009 — mobility fraction outside [0, 1] or from_edge == to_edge.
    Mobility,
    /// E010 — load burst with non-positive multiplier or negative duration.
    LoadBurst,
    /// E011 — unknown event type.
    UnknownEvent,
    /// E012 — unknown field on an event object.
    UnknownField,
    /// E013 — world has no edge servers (users cannot be covered).
    NoEdges,
    /// E014 — parameter out of its valid range (non-positive capacity,
    /// count, rate, or percentage outside [0, 100]).
    BadParam,
    /// E015 — inverted band: a `lo` bound above its `hi` bound.
    InvertedBand,
    /// E016 — schedule assigns the same request twice.
    DuplicateAssignment,
    /// E017 — schedule assigns a request to a down server.
    DownServerAssignment,
    /// E018 — schedule's summed computation cost overflows a server's γ.
    GammaOverflow,
    /// E019 — input file missing or unreadable.
    FileUnreadable,
    /// E020 — malformed JSON or unrecognized document structure.
    ParseError,
    /// W101 — offered demand exceeds aggregate service capacity per frame.
    DemandExceedsCapacity,
    /// W102 — an up server with zero γ: placements there can never serve.
    ZeroGamma,
    /// W103 — deadline pre-screen: the mean deadline is below the fastest
    /// possible completion on any reachable server.
    DeadlineInfeasible,
    /// W104 — event scheduled at or beyond the run horizon (never fires).
    EventBeyondHorizon,
    /// W105 — `server_down` with no matching `server_up` (permanent outage).
    PermanentOutage,
    /// I201 — script contains no events.
    EmptyScript,
}

impl Code {
    /// Every code, in code order (used by the fixture-coverage test).
    pub const ALL: [Code; 26] = [
        Code::ServerIndex,
        Code::EdgeIndex,
        Code::ServiceIndex,
        Code::TierIndex,
        Code::EventTime,
        Code::DownWhileDown,
        Code::UpWhileUp,
        Code::LinkPair,
        Code::Mobility,
        Code::LoadBurst,
        Code::UnknownEvent,
        Code::UnknownField,
        Code::NoEdges,
        Code::BadParam,
        Code::InvertedBand,
        Code::DuplicateAssignment,
        Code::DownServerAssignment,
        Code::GammaOverflow,
        Code::FileUnreadable,
        Code::ParseError,
        Code::DemandExceedsCapacity,
        Code::ZeroGamma,
        Code::DeadlineInfeasible,
        Code::EventBeyondHorizon,
        Code::PermanentOutage,
        Code::EmptyScript,
    ];

    /// The stable machine code (`E001`, `W101`, `I201`).
    pub fn as_str(&self) -> &'static str {
        match self {
            Code::ServerIndex => "E001",
            Code::EdgeIndex => "E002",
            Code::ServiceIndex => "E003",
            Code::TierIndex => "E004",
            Code::EventTime => "E005",
            Code::DownWhileDown => "E006",
            Code::UpWhileUp => "E007",
            Code::LinkPair => "E008",
            Code::Mobility => "E009",
            Code::LoadBurst => "E010",
            Code::UnknownEvent => "E011",
            Code::UnknownField => "E012",
            Code::NoEdges => "E013",
            Code::BadParam => "E014",
            Code::InvertedBand => "E015",
            Code::DuplicateAssignment => "E016",
            Code::DownServerAssignment => "E017",
            Code::GammaOverflow => "E018",
            Code::FileUnreadable => "E019",
            Code::ParseError => "E020",
            Code::DemandExceedsCapacity => "W101",
            Code::ZeroGamma => "W102",
            Code::DeadlineInfeasible => "W103",
            Code::EventBeyondHorizon => "W104",
            Code::PermanentOutage => "W105",
            Code::EmptyScript => "I201",
        }
    }

    /// Severity is fixed per code, not per occurrence.
    pub fn severity(&self) -> Severity {
        match self.as_str().as_bytes()[0] {
            b'E' => Severity::Error,
            b'W' => Severity::Warning,
            _ => Severity::Info,
        }
    }
}

/// One finding: a code, a location path into the document (e.g.
/// `events[3]`, `catalog`, `assignments[0]`), and a human message.
#[derive(Clone, Debug, PartialEq)]
pub struct Diagnostic {
    pub code: Code,
    pub at: String,
    pub message: String,
}

impl Diagnostic {
    /// The canonical one-line rendering:
    /// `error[E001] events[3]: server 12 out of range (10 servers)`.
    pub fn render(&self) -> String {
        format!(
            "{}[{}] {}: {}",
            self.code.severity().as_str(),
            self.code.as_str(),
            self.at,
            self.message
        )
    }
}

/// An accumulating, sortable diagnostic list.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Diagnostics {
    items: Vec<Diagnostic>,
}

impl Diagnostics {
    pub fn new() -> Diagnostics {
        Diagnostics::default()
    }

    pub fn push(&mut self, code: Code, at: impl AsRef<str>, message: impl Into<String>) {
        self.items
            .push(Diagnostic { code, at: at.as_ref().to_string(), message: message.into() });
    }

    pub fn extend(&mut self, other: Diagnostics) {
        self.items.extend(other.items);
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn has_errors(&self) -> bool {
        self.count(Severity::Error) > 0
    }

    pub fn count(&self, sev: Severity) -> usize {
        self.items.iter().filter(|d| d.code.severity() == sev).count()
    }

    /// Sorted view: severity, then code, then location, then message —
    /// a total deterministic order, so rendering is byte-stable.
    pub fn sorted(&self) -> Vec<&Diagnostic> {
        let mut v: Vec<&Diagnostic> = self.items.iter().collect();
        v.sort_by(|a, b| {
            (a.code.severity(), a.code.as_str(), &a.at, &a.message).cmp(&(
                b.code.severity(),
                b.code.as_str(),
                &b.at,
                &b.message,
            ))
        });
        v
    }

    /// Does any diagnostic carry `code`? (Fixture tests key off this.)
    pub fn has_code(&self, code: Code) -> bool {
        self.items.iter().any(|d| d.code == code)
    }

    /// One line per diagnostic, sorted; empty string when clean.
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        for d in self.sorted() {
            out.push_str(&d.render());
            out.push('\n');
        }
        out
    }

    /// Byte-stable JSON (sorted diagnostics, `Json::obj` key order).
    pub fn to_json(&self) -> Json {
        let diags = self.sorted().into_iter().map(|d| {
            Json::obj(vec![
                ("at", Json::str(&d.at)),
                ("code", Json::str(d.code.as_str())),
                ("message", Json::str(&d.message)),
                ("severity", Json::str(d.code.severity().as_str())),
            ])
        });
        Json::obj(vec![
            ("diagnostics", Json::arr(diags)),
            (
                "summary",
                Json::obj(vec![
                    ("errors", Json::num(self.count(Severity::Error) as f64)),
                    ("infos", Json::num(self.count(Severity::Info) as f64)),
                    ("warnings", Json::num(self.count(Severity::Warning) as f64)),
                ]),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codes_are_unique_and_severity_consistent() {
        let mut seen = std::collections::BTreeSet::new();
        for c in Code::ALL {
            assert!(seen.insert(c.as_str()), "duplicate code {}", c.as_str());
            let sev = c.severity();
            match c.as_str().as_bytes()[0] {
                b'E' => assert_eq!(sev, Severity::Error),
                b'W' => assert_eq!(sev, Severity::Warning),
                b'I' => assert_eq!(sev, Severity::Info),
                _ => panic!("bad code prefix {}", c.as_str()),
            }
        }
        assert_eq!(seen.len(), Code::ALL.len());
    }

    #[test]
    fn rendering_is_sorted_and_stable() {
        let mut d = Diagnostics::new();
        d.push(Code::ZeroGamma, "gamma[1]", "zero");
        d.push(Code::ServerIndex, "events[2]", "b");
        d.push(Code::ServerIndex, "events[1]", "a");
        let text = d.render_text();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].starts_with("error[E001] events[1]"));
        assert!(lines[1].starts_with("error[E001] events[2]"));
        assert!(lines[2].starts_with("warning[W102]"));
        // Rendering twice is byte-identical.
        assert_eq!(text, d.render_text());
        assert_eq!(d.to_json().dump(), d.to_json().dump());
    }

    #[test]
    fn counts_by_severity() {
        let mut d = Diagnostics::new();
        d.push(Code::ServerIndex, "x", "m");
        d.push(Code::DemandExceedsCapacity, "y", "m");
        d.push(Code::EmptyScript, "z", "m");
        assert!(d.has_errors());
        assert_eq!(d.count(Severity::Error), 1);
        assert_eq!(d.count(Severity::Warning), 1);
        assert_eq!(d.count(Severity::Info), 1);
        assert!(d.has_code(Code::EmptyScript));
        assert!(!d.has_code(Code::TierIndex));
    }
}
