//! Static checks over world configurations (`config::scenario_from_json`
//! documents the format): structural sanity of topology/catalog/workload
//! parameters, aggregate capacity vs offered demand, and the deadline
//! feasibility pre-screen. Pure — builds no topology larger than the
//! parameter structs themselves.

use crate::model::service::CatalogParams;
use crate::model::topology::TopologyParams;
use crate::verify::diag::{Code, Diagnostics};
use crate::workload::ScenarioParams;

/// The offered-load context of a DES/scenario run, when known. Without
/// it the capacity and horizon checks cannot fire (a bare world file
/// has no load attached).
#[derive(Clone, Copy, Debug)]
pub struct DesLoad {
    pub arrival_rate_per_s: f64,
    pub frame_ms: f64,
    pub horizon_ms: f64,
}

/// Summed default edge γ for the paper topology: `paper_default` cycles
/// edge classes Small/Medium/Large (γ 2/3/4) by index.
fn paper_edge_gamma_sum(t: &TopologyParams) -> f64 {
    use crate::model::server::ServerClass;
    let edge_classes =
        [ServerClass::EdgeSmall, ServerClass::EdgeMedium, ServerClass::EdgeLarge];
    (0..t.num_edge).map(|i| edge_classes[i % 3].default_gamma()).sum()
}

/// The fastest completion any request could see: the optimistic bound
/// over local processing on the fastest edge class (speed 0.85) and the
/// fastest cloud path (speed 0.9 plus the minimum backhaul delay).
/// Mirrors the constants in `ServiceCatalog::synthetic`.
fn fastest_completion_ms(t: &TopologyParams, c: &CatalogParams) -> f64 {
    let edge_best = c.edge_proc_lo_ms * 0.85;
    let cloud_best = c.cloud_proc_ms * 0.9 + t.edge_cloud_ms * (1.0 - t.jitter).max(0.0);
    edge_best.min(cloud_best)
}

/// Mean per-request edge processing time: band midpoint scaled by the
/// average tier slowdown (tiers are drawn uniformly in expectation).
fn mean_edge_proc_ms(c: &CatalogParams) -> f64 {
    let mid = 0.5 * (c.edge_proc_lo_ms + c.edge_proc_hi_ms);
    let mean_slow = (0..c.num_tiers)
        .map(|l| c.tier_slowdown.powi(l as i32))
        .sum::<f64>()
        / c.num_tiers.max(1) as f64;
    mid * mean_slow
}

fn check_positive(out: &mut Diagnostics, at: &str, name: &str, v: f64) {
    if !v.is_finite() || v <= 0.0 {
        out.push(Code::BadParam, at, format!("{name} must be finite and > 0 (got {v})"));
    }
}

fn check_band(out: &mut Diagnostics, at: &str, name: &str, lo: f64, hi: f64) {
    if lo > hi {
        out.push(Code::InvertedBand, at, format!("{name} band inverted: lo {lo} > hi {hi}"));
    }
}

/// Verify a world (topology + catalog + workload parameters), plus the
/// demand/deadline screens when the offered load is known.
pub fn verify_scenario(s: &ScenarioParams, load: Option<&DesLoad>) -> Diagnostics {
    let mut out = Diagnostics::new();
    let t = &s.topology;
    let c = &s.catalog;
    let w = &s.workload;

    // -- topology ---------------------------------------------------------
    if t.num_edge == 0 {
        out.push(
            Code::NoEdges,
            "topology",
            "world has no edge servers — users cannot be covered (the cloud is unreachable directly)",
        );
    }
    if t.edge_edge_ms < 0.0 || t.edge_cloud_ms < 0.0 {
        out.push(Code::BadParam, "topology", "link delays must be >= 0");
    }
    if !(0.0..1.0).contains(&t.jitter) {
        out.push(Code::BadParam, "topology", format!("jitter {} must be in [0, 1)", t.jitter));
    }

    // -- catalog ----------------------------------------------------------
    if c.num_services == 0 {
        out.push(Code::BadParam, "catalog", "num_services must be > 0");
    }
    if c.num_tiers == 0 {
        out.push(Code::BadParam, "catalog", "num_tiers must be > 0");
    }
    check_positive(&mut out, "catalog", "edge_proc_lo_ms", c.edge_proc_lo_ms);
    check_positive(&mut out, "catalog", "cloud_proc_ms", c.cloud_proc_ms);
    check_positive(&mut out, "catalog", "tier_slowdown", c.tier_slowdown);
    check_band(&mut out, "catalog", "edge_proc_ms", c.edge_proc_lo_ms, c.edge_proc_hi_ms);
    check_band(&mut out, "catalog", "accuracy_pct", c.accuracy_lo_pct, c.accuracy_hi_pct);
    if c.accuracy_lo_pct < 0.0 || c.accuracy_hi_pct > 100.0 {
        out.push(
            Code::BadParam,
            "catalog",
            format!(
                "accuracy band [{}, {}] must lie in [0, 100]",
                c.accuracy_lo_pct, c.accuracy_hi_pct
            ),
        );
    }

    // -- workload ---------------------------------------------------------
    check_positive(&mut out, "workload", "deadline_mean_ms", w.deadline_mean_ms);
    check_positive(&mut out, "workload", "max_completion_ms", w.max_completion_ms);
    check_band(&mut out, "workload", "payload_bytes", w.payload_lo_bytes as f64, w.payload_hi_bytes as f64);
    if w.w_accuracy < 0.0 || w.w_completion < 0.0 {
        out.push(Code::BadParam, "workload", "objective weights must be >= 0");
    }

    // The screens below need structurally valid inputs.
    if out.has_errors() {
        return out;
    }

    // -- deadline feasibility pre-screen ----------------------------------
    let fastest = fastest_completion_ms(t, c);
    if w.deadline_mean_ms < fastest {
        out.push(
            Code::DeadlineInfeasible,
            "workload",
            format!(
                "mean deadline {} ms is below the fastest possible completion {:.0} ms on any reachable server — most requests can never be satisfied",
                w.deadline_mean_ms, fastest
            ),
        );
    }

    // -- demand vs capacity -----------------------------------------------
    if let Some(l) = load {
        check_positive(&mut out, "des", "arrival_rate_per_s", l.arrival_rate_per_s);
        check_positive(&mut out, "des", "frame_ms", l.frame_ms);
        check_positive(&mut out, "des", "horizon_ms", l.horizon_ms);
        if !out.has_errors() {
            // Offered requests per frame vs how many the aggregate γ can
            // retire per frame (each γ slot turns over every mean-proc
            // interval). A coarse screen: it flags saturated sweeps, not
            // marginal ones.
            let offered = l.arrival_rate_per_s * l.frame_ms / 1e3;
            let edge_turnover = l.frame_ms / mean_edge_proc_ms(c).max(1e-9);
            let cloud_turnover = l.frame_ms / (c.cloud_proc_ms).max(1e-9);
            use crate::model::server::ServerClass;
            let edge_capacity: f64 = paper_edge_gamma_sum(t) * edge_turnover;
            let cloud_capacity =
                t.num_cloud as f64 * ServerClass::Cloud.default_gamma() * cloud_turnover;
            let capacity = edge_capacity + cloud_capacity;
            if offered > capacity {
                out.push(
                    Code::DemandExceedsCapacity,
                    "des",
                    format!(
                        "offered load {:.0} requests/frame exceeds estimated aggregate service capacity {:.0}/frame — expect heavy drops",
                        offered, capacity
                    ),
                );
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_default_world_is_clean() {
        let d = verify_scenario(&ScenarioParams::default(), None);
        assert!(d.is_empty(), "{}", d.render_text());
    }

    #[test]
    fn paper_default_with_moderate_load_is_clean() {
        let load =
            DesLoad { arrival_rate_per_s: 8.0, frame_ms: 3000.0, horizon_ms: 60_000.0 };
        let d = verify_scenario(&ScenarioParams::default(), Some(&load));
        assert!(d.is_empty(), "{}", d.render_text());
    }

    #[test]
    fn saturating_load_warns() {
        let load =
            DesLoad { arrival_rate_per_s: 500.0, frame_ms: 3000.0, horizon_ms: 60_000.0 };
        let d = verify_scenario(&ScenarioParams::default(), Some(&load));
        assert!(d.has_code(Code::DemandExceedsCapacity), "{}", d.render_text());
        assert!(!d.has_errors());
    }

    #[test]
    fn no_edges_is_an_error() {
        let mut s = ScenarioParams::default();
        s.topology.num_edge = 0;
        assert!(verify_scenario(&s, None).has_code(Code::NoEdges));
    }

    #[test]
    fn inverted_bands_and_bad_params_flagged() {
        let mut s = ScenarioParams::default();
        s.catalog.edge_proc_lo_ms = 2000.0;
        s.catalog.edge_proc_hi_ms = 1000.0;
        let d = verify_scenario(&s, None);
        assert!(d.has_code(Code::InvertedBand));

        let mut s = ScenarioParams::default();
        s.catalog.num_tiers = 0;
        assert!(verify_scenario(&s, None).has_code(Code::BadParam));
    }

    #[test]
    fn impossible_deadline_warns() {
        let mut s = ScenarioParams::default();
        s.workload.deadline_mean_ms = 100.0;
        let d = verify_scenario(&s, None);
        assert!(d.has_code(Code::DeadlineInfeasible), "{}", d.render_text());
        assert!(!d.has_errors());
    }

    #[test]
    fn default_deadline_clears_the_prescreen() {
        // Default cloud path: 300·0.9 + 60·0.8 = 318 ms < 1000 ms mean.
        let s = ScenarioParams::default();
        assert!(fastest_completion_ms(&s.topology, &s.catalog) < s.workload.deadline_mean_ms);
    }
}
