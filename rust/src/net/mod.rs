//! Network substrate: link models, the paper's adaptive bandwidth
//! estimator, and a simulated wireless channel for the serving path.
//!
//! The paper's testbed measures an average bandwidth of ~600 bytes/ms on
//! the edge↔cloud path and updates its expectation each round with
//! `E[B_{t+1}] = (B_t + B_{t-1}) / 2`; the expected per-image
//! communication delay is then `size / E[B]`.

use crate::util::rng::Rng;

/// The paper's two-sample moving-average bandwidth estimator.
#[derive(Clone, Debug)]
pub struct BandwidthEstimator {
    /// B_t (bytes/ms): most recent observation.
    b_t: f64,
    /// B_{t-1} (bytes/ms).
    b_prev: f64,
}

impl BandwidthEstimator {
    /// Start from an initial historical estimate (paper: 600 bytes/ms).
    pub fn new(initial_bytes_per_ms: f64) -> BandwidthEstimator {
        assert!(initial_bytes_per_ms > 0.0);
        BandwidthEstimator { b_t: initial_bytes_per_ms, b_prev: initial_bytes_per_ms }
    }

    /// `E[B_{t+1}] = (B_t + B_{t-1}) / 2`.
    pub fn expected_bytes_per_ms(&self) -> f64 {
        0.5 * (self.b_t + self.b_prev)
    }

    /// Feed one observed bandwidth sample (bytes/ms).
    pub fn observe(&mut self, bytes_per_ms: f64) {
        if bytes_per_ms.is_finite() && bytes_per_ms > 0.0 {
            self.b_prev = self.b_t;
            self.b_t = bytes_per_ms;
        }
    }

    /// Expected forwarding delay for a payload under the current estimate.
    pub fn expected_delay_ms(&self, payload_bytes: u64) -> f64 {
        payload_bytes as f64 / self.expected_bytes_per_ms()
    }
}

/// A (directed) link with stochastic bandwidth — the simulated wireless
/// channel of the testbed analog.
#[derive(Clone, Debug)]
pub struct Link {
    /// Mean bandwidth (bytes/ms).
    pub mean_bytes_per_ms: f64,
    /// Relative jitter σ/μ of the per-transfer bandwidth draw.
    pub jitter: f64,
    /// Fixed propagation/forwarder latency (ms) added per transfer.
    pub propagation_ms: f64,
}

impl Link {
    pub fn new(mean_bytes_per_ms: f64, jitter: f64, propagation_ms: f64) -> Link {
        assert!(mean_bytes_per_ms > 0.0 && jitter >= 0.0 && propagation_ms >= 0.0);
        Link { mean_bytes_per_ms, jitter, propagation_ms }
    }

    /// Paper-calibrated defaults: B ≈ 600 bytes/ms edge↔cloud through the
    /// RP3 forwarder; edge↔edge is a single hop and slightly faster.
    pub fn edge_cloud_default() -> Link {
        Link::new(600.0, 0.25, 8.0)
    }

    pub fn edge_edge_default() -> Link {
        Link::new(900.0, 0.2, 3.0)
    }

    /// Sample an actual transfer: returns (delay_ms, realized bytes/ms).
    pub fn transfer(&self, payload_bytes: u64, rng: &mut Rng) -> (f64, f64) {
        let bw = rng
            .normal(self.mean_bytes_per_ms, self.jitter * self.mean_bytes_per_ms)
            .max(self.mean_bytes_per_ms * 0.05);
        let delay = self.propagation_ms + payload_bytes as f64 / bw;
        (delay, bw)
    }

    /// Deterministic expected delay (used to build comm matrices).
    pub fn expected_delay_ms(&self, payload_bytes: u64) -> f64 {
        self.propagation_ms + payload_bytes as f64 / self.mean_bytes_per_ms
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn estimator_is_two_sample_average() {
        let mut e = BandwidthEstimator::new(600.0);
        assert_eq!(e.expected_bytes_per_ms(), 600.0);
        e.observe(800.0);
        // B_t=800, B_{t-1}=600 → 700.
        assert_eq!(e.expected_bytes_per_ms(), 700.0);
        e.observe(400.0);
        assert_eq!(e.expected_bytes_per_ms(), 600.0);
    }

    #[test]
    fn estimator_converges_on_constant_channel() {
        let mut e = BandwidthEstimator::new(600.0);
        for _ in 0..10 {
            e.observe(1000.0);
        }
        assert_eq!(e.expected_bytes_per_ms(), 1000.0);
    }

    #[test]
    fn estimator_ignores_bad_samples() {
        let mut e = BandwidthEstimator::new(600.0);
        e.observe(f64::NAN);
        e.observe(-5.0);
        e.observe(0.0);
        assert_eq!(e.expected_bytes_per_ms(), 600.0);
    }

    #[test]
    fn expected_delay_uses_estimate() {
        let e = BandwidthEstimator::new(600.0);
        assert!((e.expected_delay_ms(6000) - 10.0).abs() < 1e-12);
    }

    #[test]
    fn transfer_delay_reasonable() {
        let link = Link::edge_cloud_default();
        let mut rng = Rng::new(1);
        let mut acc = 0.0;
        let n = 5000;
        for _ in 0..n {
            let (d, bw) = link.transfer(12_000, &mut rng);
            assert!(d > link.propagation_ms);
            assert!(bw > 0.0);
            acc += d;
        }
        let mean = acc / n as f64;
        let expect = link.expected_delay_ms(12_000);
        // Jensen: E[1/bw] ≥ 1/E[bw], so the observed mean is a bit above.
        assert!(mean > expect * 0.95 && mean < expect * 1.35, "mean={mean} expect={expect}");
    }

    #[test]
    fn estimator_tracks_drifting_channel() {
        let mut e = BandwidthEstimator::new(600.0);
        let link = Link::new(300.0, 0.1, 0.0);
        let mut rng = Rng::new(2);
        for _ in 0..50 {
            let (_, bw) = link.transfer(10_000, &mut rng);
            e.observe(bw);
        }
        let est = e.expected_bytes_per_ms();
        assert!((est - 300.0).abs() < 100.0, "est={est}");
    }
}
