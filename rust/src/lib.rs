//! # edgeus — Optimal Accuracy-Time Trade-off for DL Services at the Edge
//!
//! A production-shaped reproduction of Hosseinzadeh et al., *"Optimal
//! Accuracy-Time Trade-off for Deep Learning Services in Edge Computing
//! Systems"* (2020), as a three-layer rust + JAX + Pallas serving stack:
//!
//! * **L3 (this crate)** — the coordinator: the MUS user-satisfaction
//!   model, the GUS greedy scheduler, five baseline heuristics, an exact
//!   branch-and-bound solver, the Monte-Carlo numerical harness, and a
//!   live serving runtime (admission queues → periodic decisions →
//!   dispatch → real model execution).
//! * **L2** — EdgeNet, a JAX CNN family with accuracy tiers
//!   (`python/compile/model.py`), AOT-lowered to HLO text artifacts.
//! * **L1** — a Pallas tiled GEMM kernel
//!   (`python/compile/kernels/matmul.py`) carrying all model FLOPs.
//!
//! Python never runs on the request path: `runtime` loads the compiled
//! artifacts through PJRT and `serving` drives them from rust threads.
//!
//! ## Quick tour
//!
//! ```no_run
//! use edgeus::prelude::*;
//!
//! // Draw a paper-default instance and schedule it with GUS.
//! let mut rng = Rng::new(7);
//! let inst = build_instance(&ScenarioParams::default(), &mut rng);
//! let schedule = Gus::default().schedule(&inst, &mut rng);
//! println!("satisfied: {:.1}%", schedule.satisfied_pct(&inst));
//! ```

pub mod benchkit;
pub mod config;
pub mod coordinator;
pub mod figures;
pub mod metrics;
pub mod model;
pub mod net;
pub mod obs;
pub mod runtime;
pub mod scenario;
pub mod serving;
pub mod sim;
pub mod util;
pub mod verify;
pub mod workload;

/// Common imports for examples and benches.
pub mod prelude {
    pub use crate::coordinator::baselines::{
        HappyCommunication, HappyComputation, LocalAll, OffloadAll, RandomAssignment,
    };
    pub use crate::coordinator::gus::Gus;
    pub use crate::coordinator::ilp::BranchAndBound;
    pub use crate::coordinator::{
        all_schedulers, scheduler_by_name, Assignment, CapacityTracker, ConstraintMode,
        SchedScratch, Schedule, Scheduler,
    };
    pub use crate::model::{
        Candidate, Placement, ProblemInstance, Request, Server, ServerClass, ServerId,
        ServiceCatalog, ServiceId, TierId, Topology,
    };
    pub use crate::obs::{chrome_trace, prometheus, DropReason, Recorder};
    pub use crate::scenario::{run_sweep, Script, SweepConfig};
    pub use crate::sim::{Des, DesConfig, DesReport, FrameExplain, MonteCarlo, PolicyStats};
    pub use crate::util::rng::Rng;
    pub use crate::verify::{Diagnostics, Severity};
    pub use crate::workload::{build_instance, ScenarioParams, WorkloadParams};
}
