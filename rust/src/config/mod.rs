//! Config system: JSON scenario files mapping onto the workload/topology/
//! catalog parameter structs, with paper defaults for anything omitted.
//!
//! ```json
//! {
//!   "topology": {"num_edge": 9, "num_cloud": 1},
//!   "catalog":  {"num_services": 100, "num_tiers": 10},
//!   "workload": {"num_requests": 100, "accuracy_mean_pct": 45.0},
//!   "runs": 2000, "seed": 7
//! }
//! ```

use crate::model::service::CatalogParams;
use crate::model::topology::TopologyParams;
use crate::sim::MonteCarlo;
use crate::util::json::Json;
use crate::workload::{ScenarioParams, WorkloadParams};
use anyhow::{Context, Result};

fn f(j: &Json, key: &str, default: f64) -> f64 {
    j.get(key).as_f64().unwrap_or(default)
}

fn u(j: &Json, key: &str, default: usize) -> usize {
    j.get(key).as_usize().unwrap_or(default)
}

pub fn topology_from_json(j: &Json) -> TopologyParams {
    let d = TopologyParams::default();
    TopologyParams {
        num_edge: u(j, "num_edge", d.num_edge),
        num_cloud: u(j, "num_cloud", d.num_cloud),
        edge_edge_ms: f(j, "edge_edge_ms", d.edge_edge_ms),
        edge_cloud_ms: f(j, "edge_cloud_ms", d.edge_cloud_ms),
        jitter: f(j, "jitter", d.jitter),
    }
}

pub fn catalog_from_json(j: &Json) -> CatalogParams {
    let d = CatalogParams::default();
    CatalogParams {
        num_services: u(j, "num_services", d.num_services),
        num_tiers: u(j, "num_tiers", d.num_tiers),
        edge_proc_lo_ms: f(j, "edge_proc_lo_ms", d.edge_proc_lo_ms),
        edge_proc_hi_ms: f(j, "edge_proc_hi_ms", d.edge_proc_hi_ms),
        cloud_proc_ms: f(j, "cloud_proc_ms", d.cloud_proc_ms),
        accuracy_lo_pct: f(j, "accuracy_lo_pct", d.accuracy_lo_pct),
        accuracy_hi_pct: f(j, "accuracy_hi_pct", d.accuracy_hi_pct),
        tier_slowdown: f(j, "tier_slowdown", d.tier_slowdown),
        tier_cost_growth: f(j, "tier_cost_growth", d.tier_cost_growth),
    }
}

pub fn workload_from_json(j: &Json) -> WorkloadParams {
    let d = WorkloadParams::default();
    WorkloadParams {
        num_requests: u(j, "num_requests", d.num_requests),
        accuracy_mean_pct: f(j, "accuracy_mean_pct", d.accuracy_mean_pct),
        accuracy_std_pct: f(j, "accuracy_std_pct", d.accuracy_std_pct),
        deadline_mean_ms: f(j, "deadline_mean_ms", d.deadline_mean_ms),
        deadline_std_ms: f(j, "deadline_std_ms", d.deadline_std_ms),
        queue_delay_max_ms: f(j, "queue_delay_max_ms", d.queue_delay_max_ms),
        w_accuracy: f(j, "w_accuracy", d.w_accuracy),
        w_completion: f(j, "w_completion", d.w_completion),
        payload_lo_bytes: j.get("payload_lo_bytes").as_usize().unwrap_or(d.payload_lo_bytes as usize)
            as u64,
        payload_hi_bytes: j.get("payload_hi_bytes").as_usize().unwrap_or(d.payload_hi_bytes as usize)
            as u64,
        max_completion_ms: f(j, "max_completion_ms", d.max_completion_ms),
    }
}

pub fn scenario_from_json(j: &Json) -> ScenarioParams {
    ScenarioParams {
        topology: topology_from_json(j.get("topology")),
        catalog: catalog_from_json(j.get("catalog")),
        workload: workload_from_json(j.get("workload")),
    }
}

/// Parse a complete Monte-Carlo experiment description.
pub fn montecarlo_from_json(j: &Json) -> MonteCarlo {
    let d = MonteCarlo::default();
    MonteCarlo {
        scenario: scenario_from_json(j),
        runs: u(j, "runs", d.runs),
        base_seed: j.get("seed").as_i64().map(|s| s as u64).unwrap_or(d.base_seed),
        threads: u(j, "threads", d.threads),
    }
}

/// Load a scenario/experiment config from a JSON file.
pub fn load_montecarlo(path: &str) -> Result<MonteCarlo> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let j = Json::parse(&text).with_context(|| format!("parsing {path}"))?;
    Ok(montecarlo_from_json(&j))
}

pub fn scenario_to_json(s: &ScenarioParams) -> Json {
    Json::obj(vec![
        (
            "topology",
            Json::obj(vec![
                ("num_edge", Json::num(s.topology.num_edge as f64)),
                ("num_cloud", Json::num(s.topology.num_cloud as f64)),
                ("edge_edge_ms", Json::num(s.topology.edge_edge_ms)),
                ("edge_cloud_ms", Json::num(s.topology.edge_cloud_ms)),
                ("jitter", Json::num(s.topology.jitter)),
            ]),
        ),
        (
            "catalog",
            Json::obj(vec![
                ("num_services", Json::num(s.catalog.num_services as f64)),
                ("num_tiers", Json::num(s.catalog.num_tiers as f64)),
                ("edge_proc_lo_ms", Json::num(s.catalog.edge_proc_lo_ms)),
                ("edge_proc_hi_ms", Json::num(s.catalog.edge_proc_hi_ms)),
                ("cloud_proc_ms", Json::num(s.catalog.cloud_proc_ms)),
                ("accuracy_lo_pct", Json::num(s.catalog.accuracy_lo_pct)),
                ("accuracy_hi_pct", Json::num(s.catalog.accuracy_hi_pct)),
                ("tier_slowdown", Json::num(s.catalog.tier_slowdown)),
            ]),
        ),
        (
            "workload",
            Json::obj(vec![
                ("num_requests", Json::num(s.workload.num_requests as f64)),
                ("accuracy_mean_pct", Json::num(s.workload.accuracy_mean_pct)),
                ("accuracy_std_pct", Json::num(s.workload.accuracy_std_pct)),
                ("deadline_mean_ms", Json::num(s.workload.deadline_mean_ms)),
                ("deadline_std_ms", Json::num(s.workload.deadline_std_ms)),
                ("queue_delay_max_ms", Json::num(s.workload.queue_delay_max_ms)),
                ("w_accuracy", Json::num(s.workload.w_accuracy)),
                ("w_completion", Json::num(s.workload.w_completion)),
                ("max_completion_ms", Json::num(s.workload.max_completion_ms)),
            ]),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let mc = montecarlo_from_json(&Json::parse("{}").unwrap());
        assert_eq!(mc.scenario.topology.num_edge, 9);
        assert_eq!(mc.scenario.topology.num_cloud, 1);
        assert_eq!(mc.scenario.catalog.num_services, 100);
        assert_eq!(mc.scenario.catalog.num_tiers, 10);
        assert_eq!(mc.scenario.workload.num_requests, 100);
        assert_eq!(mc.scenario.workload.accuracy_mean_pct, 45.0);
        assert_eq!(mc.scenario.workload.deadline_mean_ms, 1000.0);
        assert_eq!(mc.scenario.workload.max_completion_ms, 12_000.0);
    }

    #[test]
    fn overrides_apply() {
        let j = Json::parse(
            r#"{"topology":{"num_edge":4},"workload":{"num_requests":50},"runs":10,"seed":99}"#,
        )
        .unwrap();
        let mc = montecarlo_from_json(&j);
        assert_eq!(mc.scenario.topology.num_edge, 4);
        assert_eq!(mc.scenario.workload.num_requests, 50);
        assert_eq!(mc.runs, 10);
        assert_eq!(mc.base_seed, 99);
    }

    #[test]
    fn json_round_trip_preserves_scenario() {
        let s = ScenarioParams::default();
        let j = scenario_to_json(&s);
        let s2 = scenario_from_json(&Json::parse(&j.pretty()).unwrap());
        assert_eq!(s2.topology.num_edge, s.topology.num_edge);
        assert_eq!(s2.catalog.tier_slowdown, s.catalog.tier_slowdown);
        assert_eq!(s2.workload.deadline_std_ms, s.workload.deadline_std_ms);
    }

    #[test]
    fn load_from_file() {
        let dir = std::env::temp_dir().join("edgeus_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cfg.json");
        std::fs::write(&path, r#"{"runs": 3}"#).unwrap();
        let mc = load_montecarlo(path.to_str().unwrap()).unwrap();
        assert_eq!(mc.runs, 3);
    }

    #[test]
    fn load_missing_file_errors() {
        assert!(load_montecarlo("/nonexistent/x.json").is_err());
    }
}
