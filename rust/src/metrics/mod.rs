//! Metrics & reporting: satisfaction series, latency histograms, and the
//! table emitters used by the figure-regeneration harness (markdown for
//! the terminal, CSV/JSON for plotting).

use crate::obs::DropReason;
use crate::util::json::Json;
use crate::util::stats::Histogram;

/// One figure series: y (± ci) per x per policy.
#[derive(Clone, Debug)]
pub struct Series {
    pub x_label: String,
    pub y_label: String,
    pub xs: Vec<f64>,
    /// `(policy name, ys, ci95s)` — ys.len() == xs.len().
    pub policies: Vec<(String, Vec<f64>, Vec<f64>)>,
}

impl Series {
    pub fn new(x_label: &str, y_label: &str, xs: Vec<f64>) -> Series {
        Series {
            x_label: x_label.to_string(),
            y_label: y_label.to_string(),
            xs,
            policies: Vec::new(),
        }
    }

    pub fn push_policy(&mut self, name: &str, ys: Vec<f64>, cis: Vec<f64>) {
        assert_eq!(ys.len(), self.xs.len());
        assert_eq!(cis.len(), self.xs.len());
        self.policies.push((name.to_string(), ys, cis));
    }

    /// Render a terminal-friendly markdown table (rows = x, cols = policy).
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("| {} |", self.x_label));
        for (name, _, _) in &self.policies {
            out.push_str(&format!(" {name} |"));
        }
        out.push('\n');
        out.push_str("|---|");
        for _ in &self.policies {
            out.push_str("---|");
        }
        out.push('\n');
        for (i, x) in self.xs.iter().enumerate() {
            out.push_str(&format!("| {x:.0} |"));
            for (_, ys, cis) in &self.policies {
                if cis[i].is_nan() {
                    out.push_str(&format!(" {:.2} |", ys[i]));
                } else {
                    out.push_str(&format!(" {:.2} ±{:.2} |", ys[i], cis[i]));
                }
            }
            out.push('\n');
        }
        out
    }

    /// Render CSV (`x,policy1,policy1_ci,...`).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.x_label.replace(',', ";"));
        for (name, _, _) in &self.policies {
            out.push_str(&format!(",{name},{name}_ci95"));
        }
        out.push('\n');
        for (i, x) in self.xs.iter().enumerate() {
            out.push_str(&format!("{x}"));
            for (_, ys, cis) in &self.policies {
                out.push_str(&format!(",{},{}", ys[i], cis[i]));
            }
            out.push('\n');
        }
        out
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("x_label", Json::str(&self.x_label)),
            ("y_label", Json::str(&self.y_label)),
            ("xs", Json::arr(self.xs.iter().map(|x| Json::num(*x)))),
            (
                "policies",
                Json::arr(self.policies.iter().map(|(name, ys, cis)| {
                    Json::obj(vec![
                        ("name", Json::str(name)),
                        ("ys", Json::arr(ys.iter().map(|y| Json::num(*y)))),
                        ("ci95", Json::arr(cis.iter().map(|c| Json::num(*c)))),
                    ])
                })),
            ),
        ])
    }
}

/// One scenario phase of a serving run: the stretch of world time between
/// two applied scripted events (or run start/end). Requests are assigned
/// to phases by arrival time, so phase totals partition the run's
/// requests exactly.
#[derive(Clone, Debug, Default)]
pub struct PhaseMetrics {
    /// The applied event that opened this phase (`"start"` for the prefix
    /// before the first event).
    pub label: String,
    /// Phase start, simulated ms.
    pub from_ms: f64,
    pub requests: u64,
    pub served: u64,
    pub satisfied: u64,
    pub dropped: u64,
}

impl PhaseMetrics {
    pub fn satisfied_pct(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            100.0 * self.satisfied as f64 / self.requests as f64
        }
    }
}

/// End-to-end serving metrics for one testbed run.
#[derive(Clone, Debug)]
pub struct ServingMetrics {
    pub total_requests: u64,
    pub served: u64,
    pub satisfied: u64,
    pub dropped: u64,
    pub local: u64,
    pub offload_cloud: u64,
    pub offload_peer: u64,
    /// Drops broken down by [`DropReason`], indexed by `reason.index()`.
    /// Invariant: sums to `dropped` (see [`ServingMetrics::check_conservation`]).
    pub drop_reasons: [u64; DropReason::COUNT],
    /// End-to-end completion latency (ms).
    pub latency: Histogram,
    /// Model-inference latency alone (ms).
    pub inference: Histogram,
    pub wall_ms: f64,
    /// Scenario-phase segmentation (empty for unscripted runs). When
    /// non-empty, phase totals partition the run
    /// (see [`ServingMetrics::check_conservation`]).
    pub phases: Vec<PhaseMetrics>,
}

impl Default for ServingMetrics {
    fn default() -> Self {
        ServingMetrics {
            total_requests: 0,
            served: 0,
            satisfied: 0,
            dropped: 0,
            local: 0,
            offload_cloud: 0,
            offload_peer: 0,
            drop_reasons: [0; DropReason::COUNT],
            latency: Histogram::exponential(1.0, 2.0, 16),
            inference: Histogram::exponential(0.125, 2.0, 16),
            wall_ms: 0.0,
            phases: Vec::new(),
        }
    }
}

impl ServingMetrics {
    pub fn satisfied_pct(&self) -> f64 {
        if self.total_requests == 0 {
            return 0.0;
        }
        100.0 * self.satisfied as f64 / self.total_requests as f64
    }

    pub fn local_pct(&self) -> f64 {
        self.pct(self.local)
    }

    pub fn cloud_pct(&self) -> f64 {
        self.pct(self.offload_cloud)
    }

    pub fn peer_pct(&self) -> f64 {
        self.pct(self.offload_peer)
    }

    pub fn dropped_pct(&self) -> f64 {
        self.pct(self.dropped)
    }

    /// Record one drop with its reason; keeps `dropped` and the per-reason
    /// breakdown in lockstep so conservation cannot drift.
    pub fn add_drop(&mut self, reason: DropReason) {
        self.dropped += 1;
        self.drop_reasons[reason.index()] += 1;
    }

    /// Drops attributed to `reason`.
    pub fn drops(&self, reason: DropReason) -> u64 {
        self.drop_reasons[reason.index()]
    }

    /// Verify the request-conservation invariants:
    /// `served + dropped == total_requests` and the per-reason drop
    /// breakdown sums to `dropped`.
    pub fn check_conservation(&self) -> Result<(), String> {
        let reason_sum: u64 = self.drop_reasons.iter().sum();
        if reason_sum != self.dropped {
            return Err(format!(
                "drop reasons sum to {reason_sum} but dropped = {}",
                self.dropped
            ));
        }
        if self.served + self.dropped != self.total_requests {
            return Err(format!(
                "served ({}) + dropped ({}) != total_requests ({})",
                self.served, self.dropped, self.total_requests
            ));
        }
        if !self.phases.is_empty() {
            let (mut req, mut srv, mut sat, mut drp) = (0u64, 0u64, 0u64, 0u64);
            for p in &self.phases {
                if p.served + p.dropped != p.requests {
                    return Err(format!(
                        "phase '{}': served ({}) + dropped ({}) != requests ({})",
                        p.label, p.served, p.dropped, p.requests
                    ));
                }
                if p.satisfied > p.served {
                    return Err(format!(
                        "phase '{}': satisfied ({}) > served ({})",
                        p.label, p.satisfied, p.served
                    ));
                }
                req += p.requests;
                srv += p.served;
                sat += p.satisfied;
                drp += p.dropped;
            }
            if (req, srv, sat, drp)
                != (self.total_requests, self.served, self.satisfied, self.dropped)
            {
                return Err(format!(
                    "phase totals ({req}/{srv}/{sat}/{drp}) do not partition the run \
                     ({}/{}/{}/{})",
                    self.total_requests, self.served, self.satisfied, self.dropped
                ));
            }
        }
        Ok(())
    }

    /// Markdown table of the scenario-phase segmentation; empty string for
    /// unscripted runs.
    pub fn phases_markdown(&self) -> String {
        if self.phases.is_empty() {
            return String::new();
        }
        let mut out = String::from(
            "| phase | from (s) | requests | served | satisfied | dropped |\n\
             |---|---|---|---|---|---|\n",
        );
        for p in &self.phases {
            out.push_str(&format!(
                "| {} | {:.1} | {} | {} | {} ({:.1}%) | {} |\n",
                p.label,
                p.from_ms / 1000.0,
                p.requests,
                p.served,
                p.satisfied,
                p.satisfied_pct(),
                p.dropped,
            ));
        }
        out
    }

    /// Human-readable per-reason drop breakdown, `-` when no drops.
    fn drop_reasons_str(&self) -> String {
        let parts: Vec<String> = DropReason::ALL
            .iter()
            .filter(|r| self.drops(**r) > 0)
            .map(|r| format!("{}: {}", r.as_str(), self.drops(*r)))
            .collect();
        if parts.is_empty() {
            "-".to_string()
        } else {
            parts.join(", ")
        }
    }

    fn pct(&self, v: u64) -> f64 {
        if self.total_requests == 0 {
            0.0
        } else {
            100.0 * v as f64 / self.total_requests as f64
        }
    }

    /// Requests per second over the run.
    pub fn throughput_rps(&self) -> f64 {
        if self.wall_ms <= 0.0 {
            return 0.0;
        }
        self.served as f64 / (self.wall_ms / 1000.0)
    }

    pub fn summary_markdown(&self) -> String {
        format!(
            "| metric | value |\n|---|---|\n\
             | requests | {} |\n| served | {} |\n| satisfied | {} ({:.1}%) |\n\
             | dropped | {} ({:.1}%) |\n| drop reasons | {} |\n\
             | local | {:.1}% |\n| offload→cloud | {:.1}% |\n\
             | offload→peer | {:.1}% |\n| p50 latency | {:.0} ms |\n\
             | p99 latency | {:.0} ms |\n| mean inference | {:.2} ms |\n\
             | throughput | {:.1} req/s |\n",
            self.total_requests,
            self.served,
            self.satisfied,
            self.satisfied_pct(),
            self.dropped,
            self.dropped_pct(),
            self.drop_reasons_str(),
            self.local_pct(),
            self.cloud_pct(),
            self.peer_pct(),
            self.latency.quantile(0.5),
            self.latency.quantile(0.99),
            self.inference.mean(),
            self.throughput_rps(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_markdown_and_csv_shapes() {
        let mut s = Series::new("N", "satisfied %", vec![10.0, 20.0]);
        s.push_policy("gus", vec![90.0, 80.0], vec![1.0, 1.5]);
        s.push_policy("random", vec![50.0, 40.0], vec![2.0, 2.5]);
        let md = s.to_markdown();
        assert!(md.contains("| N | gus | random |"));
        assert!(md.lines().count() == 4);
        let csv = s.to_csv();
        assert!(csv.starts_with("N,gus,gus_ci95,random,random_ci95"));
        assert_eq!(csv.lines().count(), 3);
    }

    #[test]
    #[should_panic]
    fn series_rejects_wrong_length() {
        let mut s = Series::new("N", "y", vec![1.0, 2.0]);
        s.push_policy("p", vec![1.0], vec![1.0]);
    }

    #[test]
    fn series_json_round_trip() {
        let mut s = Series::new("x", "y", vec![1.0]);
        s.push_policy("gus", vec![5.0], vec![0.1]);
        let j = s.to_json();
        let parsed = crate::util::json::Json::parse(&j.pretty()).unwrap();
        assert_eq!(parsed.get("x_label").as_str(), Some("x"));
        assert_eq!(
            parsed.get("policies").as_arr().unwrap()[0].get("name").as_str(),
            Some("gus")
        );
    }

    #[test]
    fn serving_metrics_percentages() {
        let m = ServingMetrics {
            total_requests: 10,
            served: 8,
            satisfied: 6,
            dropped: 2,
            local: 4,
            offload_cloud: 3,
            offload_peer: 1,
            wall_ms: 2000.0,
            ..ServingMetrics::default()
        };
        assert!((m.satisfied_pct() - 60.0).abs() < 1e-12);
        assert!((m.local_pct() - 40.0).abs() < 1e-12);
        assert!((m.throughput_rps() - 4.0).abs() < 1e-12);
        assert!(m.summary_markdown().contains("60.0%"));
    }

    #[test]
    fn empty_metrics_no_nan_percent() {
        let m = ServingMetrics::default();
        assert_eq!(m.satisfied_pct(), 0.0);
        assert_eq!(m.throughput_rps(), 0.0);
    }

    #[test]
    fn drop_reasons_accumulate_and_conserve() {
        let mut m = ServingMetrics { total_requests: 5, served: 2, ..ServingMetrics::default() };
        m.add_drop(DropReason::QueueFull);
        m.add_drop(DropReason::QueueFull);
        m.add_drop(DropReason::DeadlineInfeasible);
        assert_eq!(m.dropped, 3);
        assert_eq!(m.drops(DropReason::QueueFull), 2);
        assert_eq!(m.drops(DropReason::DeadlineInfeasible), 1);
        assert_eq!(m.drops(DropReason::ServerDown), 0);
        m.check_conservation().unwrap();
        let md = m.summary_markdown();
        assert!(md.contains("queue-full: 2"));
        assert!(md.contains("deadline-infeasible: 1"));
    }

    #[test]
    fn conservation_rejects_unaccounted_requests() {
        // A bare `dropped` bump without a reason breaks the breakdown sum.
        let mut m = ServingMetrics { total_requests: 2, served: 1, ..ServingMetrics::default() };
        m.dropped = 1;
        assert!(m.check_conservation().is_err());
        // And served + dropped must cover every generated request.
        let mut m = ServingMetrics { total_requests: 3, served: 1, ..ServingMetrics::default() };
        m.add_drop(DropReason::Policy);
        assert!(m.check_conservation().is_err());
        // The empty default conserves trivially.
        ServingMetrics::default().check_conservation().unwrap();
    }

    #[test]
    fn phase_totals_must_partition_the_run() {
        let mut m = ServingMetrics {
            total_requests: 6,
            served: 5,
            satisfied: 4,
            ..ServingMetrics::default()
        };
        m.add_drop(DropReason::ServerDown);
        m.phases = vec![
            PhaseMetrics {
                label: "start".into(),
                from_ms: 0.0,
                requests: 4,
                served: 4,
                satisfied: 3,
                dropped: 0,
            },
            PhaseMetrics {
                label: "server_down".into(),
                from_ms: 9000.0,
                requests: 2,
                served: 1,
                satisfied: 1,
                dropped: 1,
            },
        ];
        m.check_conservation().unwrap();
        assert!((m.phases[1].satisfied_pct() - 50.0).abs() < 1e-12);
        let md = m.phases_markdown();
        assert!(md.contains("| server_down | 9.0 | 2 | 1 | 1 (50.0%) | 1 |"), "{md}");

        // A phase losing a request breaks conservation.
        m.phases[1].requests = 1;
        m.phases[1].dropped = 0;
        assert!(m.check_conservation().is_err());
        // Unscripted runs (no phases) are exempt.
        m.phases.clear();
        m.check_conservation().unwrap();
    }
}
