//! Exact MUS solver: depth-first branch-and-bound over per-request
//! choices — the stand-in for the paper's CPLEX runs (DESIGN.md
//! §Substitutions).
//!
//! The MUS ILP (Eq. 2) decomposes per request into "pick ≤ 1 candidate";
//! the coupling is only through the γ/η capacities. B&B explores requests
//! in a fixed order, trying candidates in descending US (plus the Drop
//! branch), with:
//!
//! * an **admissible bound**: current objective + Σ best-remaining-US per
//!   request (capacities ignored) — never underestimates, so pruning is
//!   safe and the search is exact;
//! * **greedy warm start**: GUS provides the incumbent, which typically
//!   prunes most of the tree immediately;
//! * a **node budget**: beyond it the solver returns the best incumbent
//!   and marks the result inexact (benches keep instances small enough
//!   that the budget is never hit).

use crate::coordinator::gus::Gus;
use crate::coordinator::us::{
    qos_satisfied, user_satisfaction, Assignment, CapacityTracker, ConstraintMode, Schedule,
};
use crate::coordinator::{SchedScratch, Scheduler};
use crate::model::instance::Candidate;
use crate::model::ProblemInstance;
use crate::util::rng::Rng;

/// Exact solver configuration.
#[derive(Clone, Copy, Debug)]
pub struct BranchAndBound {
    /// Abort after this many explored nodes (safety valve).
    pub node_budget: u64,
    pub mode: ConstraintMode,
}

impl Default for BranchAndBound {
    fn default() -> Self {
        BranchAndBound { node_budget: 50_000_000, mode: ConstraintMode::STRICT }
    }
}

/// Result of an exact solve.
#[derive(Clone, Debug)]
pub struct SolveResult {
    pub schedule: Schedule,
    /// True iff the search space was exhausted (solution proven optimal).
    pub exact: bool,
    pub nodes: u64,
}

struct SearchState<'a> {
    inst: &'a ProblemInstance<'a>,
    /// Per request: QoS-feasible candidates, best US first.
    options: Vec<Vec<(f64, Candidate)>>,
    /// `suffix_best[i]` = Σ_{r ≥ i} max US of r (capacity-free bound).
    suffix_best: Vec<f64>,
    tracker: CapacityTracker,
    current: Vec<Option<(f64, Candidate)>>,
    current_sum: f64,
    best_sum: f64,
    best: Vec<Option<(f64, Candidate)>>,
    nodes: u64,
    budget: u64,
    exhausted: bool,
}

impl<'a> SearchState<'a> {
    fn dfs(&mut self, i: usize) {
        self.nodes += 1;
        if self.nodes > self.budget {
            self.exhausted = false;
            return;
        }
        if i == self.options.len() {
            if self.current_sum > self.best_sum + 1e-12 {
                self.best_sum = self.current_sum;
                self.best = self.current.clone();
            }
            return;
        }
        // Bound: even taking the best candidate of every remaining request
        // cannot beat the incumbent → prune.
        if self.current_sum + self.suffix_best[i] <= self.best_sum + 1e-12 {
            return;
        }
        // Branch on candidates in descending US.
        // (options are pre-sorted descending.)
        let n_opts = self.options[i].len();
        for oi in 0..n_opts {
            let (us, cand) = self.options[i][oi];
            let req = &self.inst.requests[i];
            if !self.tracker.fits(req, &cand) {
                continue;
            }
            self.tracker.commit(req, &cand);
            self.current[i] = Some((us, cand));
            self.current_sum += us;
            self.dfs(i + 1);
            self.current_sum -= us;
            self.current[i] = None;
            self.tracker.release(req, &cand);
            if self.nodes > self.budget {
                return;
            }
        }
        // Drop branch.
        self.dfs(i + 1);
    }
}

impl BranchAndBound {
    /// Solve to proven optimality (within the node budget).
    pub fn solve(&self, inst: &ProblemInstance) -> SolveResult {
        let n = inst.num_requests();
        let mut options: Vec<Vec<(f64, Candidate)>> = Vec::with_capacity(n);
        let mut cands: Vec<Candidate> = Vec::new();
        for i in 0..n {
            let req = &inst.requests[i];
            inst.candidates_into(i, &mut cands);
            let mut opts: Vec<(f64, Candidate)> = cands
                .iter()
                .copied()
                .filter(|c| !self.mode.qos || qos_satisfied(req, c))
                .map(|c| {
                    (
                        user_satisfaction(req, &c, inst.max_accuracy_pct, inst.max_completion_ms),
                        c,
                    )
                })
                // With strict QoS every option has US ≥ 0; under relaxed
                // QoS, negative-US options can never be optimal (Drop
                // gives 0), so discard them.
                .filter(|(us, _)| *us >= 0.0)
                .collect();
            opts.sort_by(|a, b| b.0.total_cmp(&a.0));
            options.push(opts);
        }
        let mut suffix_best = vec![0.0; n + 1];
        for i in (0..n).rev() {
            let best = options[i].first().map(|(us, _)| *us).unwrap_or(0.0);
            suffix_best[i] = suffix_best[i + 1] + best.max(0.0);
        }

        // Warm start with GUS.
        let warm = Gus::with_mode(self.mode).schedule(inst, &mut Rng::new(0));
        let warm_sum: f64 = warm.slots.iter().flatten().map(|a| a.us).sum();
        let warm_best: Vec<Option<(f64, Candidate)>> = warm
            .slots
            .iter()
            .map(|s| s.as_ref().map(|a| (a.us, a.candidate)))
            .collect();

        let mut state = SearchState {
            inst,
            options,
            suffix_best,
            tracker: CapacityTracker::new(inst, self.mode),
            current: vec![None; n],
            current_sum: 0.0,
            best_sum: warm_sum,
            best: warm_best,
            nodes: 0,
            budget: self.node_budget,
            exhausted: true,
        };
        state.dfs(0);

        let mut schedule = Schedule::empty(n);
        for (i, slot) in state.best.iter().enumerate() {
            if let Some((us, cand)) = slot {
                schedule.slots[i] = Some(Assignment {
                    request: inst.requests[i].id,
                    candidate: *cand,
                    us: *us,
                });
            }
        }
        SolveResult { schedule, exact: state.exhausted, nodes: state.nodes }
    }
}

impl Scheduler for BranchAndBound {
    fn name(&self) -> &'static str {
        "ilp"
    }

    fn schedule_into(
        &self,
        inst: &ProblemInstance,
        _rng: &mut Rng,
        _scratch: &mut SchedScratch,
        out: &mut Schedule,
    ) {
        // The exact search allocates its own branching structures; it is
        // deliberately excluded from hot-path sweeps (see `all_schedulers`).
        *out = self.solve(inst).schedule;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::us::validate_schedule;
    use crate::model::request::Request;
    use crate::model::server::{Server, ServerClass};
    use crate::model::service::{CatalogParams, Placement, ServiceCatalog};
    use crate::model::topology::{Topology, TopologyParams};

    fn instance(n: usize, seed: u64) -> ProblemInstance<'static> {
        let mut rng = Rng::new(seed);
        let topology = Topology::paper_default(
            &TopologyParams { num_edge: 3, num_cloud: 1, ..Default::default() },
            &mut rng,
        );
        let catalog = ServiceCatalog::synthetic(
            &CatalogParams { num_services: 3, num_tiers: 3, ..Default::default() },
            &mut rng,
        );
        let placement = Placement::full(&catalog, 4);
        let requests = (0..n)
            .map(|i| {
                Request::new(i, i % 3, i % 3)
                    .with_qos(rng.uniform(30.0, 55.0), rng.uniform(1500.0, 6000.0))
            })
            .collect();
        ProblemInstance::new(topology, catalog, placement, requests)
    }

    #[test]
    fn exact_on_small_instances() {
        let inst = instance(6, 1);
        let r = BranchAndBound::default().solve(&inst);
        assert!(r.exact);
        validate_schedule(&inst, &r.schedule, ConstraintMode::STRICT).unwrap();
    }

    #[test]
    fn optimal_at_least_gus() {
        for seed in 1..8 {
            let inst = instance(8, seed);
            let opt = BranchAndBound::default().solve(&inst);
            let gus = Gus::default().schedule(&inst, &mut Rng::new(0));
            assert!(
                opt.schedule.objective() >= gus.objective() - 1e-9,
                "seed {seed}: opt {} < gus {}",
                opt.schedule.objective(),
                gus.objective()
            );
        }
    }

    #[test]
    fn matches_brute_force_tiny() {
        // 3 requests, exhaustive cross-check against full enumeration.
        let inst = instance(3, 3);
        let opt = BranchAndBound::default().solve(&inst);
        assert!(opt.exact);

        // Brute force.
        let opts: Vec<Vec<(f64, crate::model::instance::Candidate)>> = (0..3)
            .map(|i| {
                let req = &inst.requests[i];
                inst.candidates(i)
                    .into_iter()
                    .filter(|c| qos_satisfied(req, c))
                    .map(|c| {
                        (
                            user_satisfaction(
                                req,
                                &c,
                                inst.max_accuracy_pct,
                                inst.max_completion_ms,
                            ),
                            c,
                        )
                    })
                    .collect()
            })
            .collect();
        let mut best = 0.0f64;
        let choices: Vec<isize> = vec![-1; 3];
        fn rec(
            inst: &ProblemInstance,
            opts: &[Vec<(f64, crate::model::instance::Candidate)>],
            choices: &mut Vec<isize>,
            i: usize,
            best: &mut f64,
        ) {
            if i == opts.len() {
                // Check capacities.
                let mut tracker = CapacityTracker::new(inst, ConstraintMode::STRICT);
                let mut sum = 0.0;
                for (r, &c) in choices.iter().enumerate() {
                    if c >= 0 {
                        let (us, cand) = opts[r][c as usize];
                        let req = &inst.requests[r];
                        if !tracker.fits(req, &cand) {
                            return;
                        }
                        tracker.commit(req, &cand);
                        sum += us;
                    }
                }
                if sum > *best {
                    *best = sum;
                }
                return;
            }
            for c in -1..opts[i].len() as isize {
                choices[i] = c;
                rec(inst, opts, choices, i + 1, best);
            }
        }
        rec(&inst, &opts, &mut choices.clone(), 0, &mut best);
        let got: f64 = opt.schedule.slots.iter().flatten().map(|a| a.us).sum();
        assert!((got - best).abs() < 1e-9, "bb {got} vs brute {best}");
    }

    #[test]
    fn node_budget_marks_inexact() {
        // Capacity-tight instance: the capacity-free bound cannot prove
        // the warm start optimal at the root, so the search must actually
        // explore — and trip the tiny node budget.
        let mut rng = Rng::new(4);
        let topology = Topology::explicit(
            vec![Server::new(0, ServerClass::EdgeMedium).with_capacities(3.0, 0.0)],
            vec![vec![0.0]],
        );
        let catalog = ServiceCatalog::synthetic(
            &CatalogParams { num_services: 1, num_tiers: 2, ..Default::default() },
            &mut rng,
        );
        let placement = Placement::full(&catalog, 1);
        let requests = (0..12)
            .map(|i| Request::new(i, 0, 0).with_qos(0.0, 3000.0 + 500.0 * i as f64))
            .collect();
        let inst = ProblemInstance::new(topology, catalog, placement, requests);
        let r = BranchAndBound { node_budget: 5, mode: ConstraintMode::STRICT }.solve(&inst);
        assert!(!r.exact);
        // Still returns the GUS warm start at minimum.
        let gus = Gus::default().schedule(&inst, &mut Rng::new(0));
        assert!(r.schedule.objective() >= gus.objective() - 1e-12);
    }

    #[test]
    fn capacity_coupled_instance_requires_drop() {
        // Single server, γ=1: only one of two requests can be served —
        // B&B must pick the higher-US one.
        let mut rng = Rng::new(5);
        let topology = Topology::explicit(
            vec![Server::new(0, ServerClass::EdgeMedium).with_capacities(1.0, 0.0)],
            vec![vec![0.0]],
        );
        let catalog = ServiceCatalog::synthetic(
            &CatalogParams { num_services: 1, num_tiers: 1, ..Default::default() },
            &mut rng,
        );
        let placement = Placement::full(&catalog, 1);
        let requests = vec![
            Request::new(0, 0, 0).with_qos(0.0, 2000.0),
            Request::new(1, 0, 0).with_qos(0.0, 9000.0), // larger slack → higher US
        ];
        let inst = ProblemInstance::new(topology, catalog, placement, requests);
        let r = BranchAndBound::default().solve(&inst);
        assert!(r.exact);
        assert!(r.schedule.slots[0].is_none());
        assert!(r.schedule.slots[1].is_some());
    }
}
