//! GUS — the paper's Greedy User Satisfaction algorithm (Algorithm 1).
//!
//! For each request, rank every placement-feasible (server, tier) candidate
//! by its US value and take the best one that (i) meets both QoS
//! thresholds, (ii) fits the serving server's residual computation
//! capacity γ_j, and (iii) — when offloading — fits the covering server's
//! residual communication capacity η_{s_i}. If no candidate fits, the
//! request is dropped. Residual capacities are updated after each commit.
//!
//! Worst-case complexity O(|N| · (|L||M|)² ) from the per-request sort —
//! the paper's stated bound; the sort dominates.

use crate::coordinator::rank_cache::RankCache;
use crate::coordinator::us::{
    qos_satisfied, user_satisfaction, Assignment, CapacityTracker, ConstraintMode, Schedule,
};
use crate::coordinator::{SchedScratch, Scheduler};
use crate::model::{Candidate, ProblemInstance};
use crate::util::rng::Rng;

/// The GUS policy. `mode` defaults to strict; the Happy-* baselines reuse
/// this exact machinery with one constraint relaxed.
#[derive(Clone, Copy, Debug)]
pub struct Gus {
    pub mode: ConstraintMode,
    /// Serve each request from the incremental [`RankCache`] instead of
    /// re-enumerating and re-sorting its candidates. Exact — schedules
    /// are bitwise identical either way (see `coordinator::rank_cache`);
    /// `false` is the legacy path, kept as the `gus-nocache` A/B oracle.
    pub cached: bool,
}

impl Default for Gus {
    fn default() -> Self {
        Gus { mode: ConstraintMode::STRICT, cached: true }
    }
}

impl Gus {
    pub fn with_mode(mode: ConstraintMode) -> Gus {
        Gus { mode, cached: true }
    }

    /// Disable the rank cache (the legacy enumerate+sort path).
    pub fn uncached(mut self) -> Gus {
        self.cached = false;
        self
    }

    /// Schedule with an externally-owned capacity tracker (the serving
    /// path carries residual capacities across decision frames), writing
    /// through caller-owned scratch so steady-state calls allocate
    /// nothing — the serving leader loop keeps `scratch`/`out` warm
    /// across frames exactly like the DES does.
    pub fn schedule_with_tracker(
        &self,
        inst: &ProblemInstance,
        tracker: &mut CapacityTracker,
        scratch: &mut SchedScratch,
        out: &mut Schedule,
    ) {
        let SchedScratch { cands, ranked, order, rank_cache, .. } = scratch;
        if self.cached {
            rank_cache.prepare(inst);
            self.fill_cached(inst, tracker, rank_cache, order, out);
        } else {
            self.fill(inst, tracker, cands, ranked, order, out);
        }
    }

    /// Algorithm 1 proper, writing into caller-owned buffers. In the DES
    /// every buffer arrives warm from the previous frame, so the loop
    /// runs allocation-free in steady state.
    fn fill(
        &self,
        inst: &ProblemInstance,
        tracker: &mut CapacityTracker,
        cands: &mut Vec<Candidate>,
        ranked: &mut Vec<(f64, Candidate)>,
        order: &mut Vec<usize>,
        out: &mut Schedule,
    ) {
        // lint:no-alloc:begin — Algorithm 1's inner loop; buffers arrive
        // warm from the previous frame.
        out.reset(inst.num_requests());
        // Requests are considered highest-priority-first (paper §V future
        // work); within a priority class, submission order (the paper's
        // Algorithm 1 order) is preserved.
        order.clear();
        order.extend(0..inst.num_requests());
        order.sort_by_key(|&i| std::cmp::Reverse(inst.requests[i].priority));
        for &i in order.iter() {
            let req = &inst.requests[i];
            inst.candidates_into(i, cands);
            ranked.clear();
            for &cand in cands.iter() {
                if self.mode.qos && !qos_satisfied(req, &cand) {
                    continue;
                }
                let us = user_satisfaction(req, &cand, inst.max_accuracy_pct, inst.max_completion_ms);
                // Soft-QoS mode (the paper's "special case"): thresholds
                // are suggestions, but a negative-US option is still
                // worse than dropping under the MUS objective.
                if !self.mode.qos && us < 0.0 {
                    continue;
                }
                ranked.push((us, cand));
            }
            // Sort by US descending; ties broken toward local processing
            // (no η spend), then lower tier (cheaper γ).
            ranked.sort_by(|a, b| {
                b.0.total_cmp(&a.0)
                    .then_with(|| a.1.offloaded.cmp(&b.1.offloaded))
                    .then_with(|| a.1.tier.cmp(&b.1.tier))
            });
            for (us, cand) in ranked.iter() {
                if tracker.fits(req, cand) {
                    tracker.commit(req, cand);
                    out.slots[i] = Some(Assignment {
                        request: req.id,
                        candidate: *cand,
                        us: *us,
                    });
                    break;
                }
            }
        }
        // lint:no-alloc:end
    }

    /// Algorithm 1 over the pre-ranked cache: identical decisions to
    /// [`Gus::fill`] (the walk computes the same first-fit under the same
    /// total order — see `coordinator::rank_cache`), but each request
    /// costs one pass over its class's cached list instead of an
    /// enumerate + score + sort. `cache.prepare(inst)` must have run.
    fn fill_cached(
        &self,
        inst: &ProblemInstance,
        tracker: &mut CapacityTracker,
        cache: &RankCache,
        order: &mut Vec<usize>,
        out: &mut Schedule,
    ) {
        // lint:no-alloc:begin — steady-state cached decision loop: the
        // priority order reuses warm capacity and the walk is scan-only.
        out.reset(inst.num_requests());
        order.clear();
        order.extend(0..inst.num_requests());
        order.sort_by_key(|&i| std::cmp::Reverse(inst.requests[i].priority));
        for &i in order.iter() {
            let req = &inst.requests[i];
            if let Some((us, cand)) = cache.walk_best(
                req,
                self.mode,
                inst.max_accuracy_pct,
                inst.max_completion_ms,
                tracker,
            ) {
                tracker.commit(req, &cand);
                out.slots[i] = Some(Assignment { request: req.id, candidate: cand, us });
            }
        }
        // lint:no-alloc:end
    }
}

impl Scheduler for Gus {
    fn name(&self) -> &'static str {
        if self.cached {
            "gus"
        } else {
            "gus-nocache"
        }
    }

    fn schedule_into(
        &self,
        inst: &ProblemInstance,
        _rng: &mut Rng,
        scratch: &mut SchedScratch,
        out: &mut Schedule,
    ) {
        let SchedScratch { cands, ranked, order, tracker, rank_cache, .. } = scratch;
        tracker.reset(inst, self.mode);
        if self.cached {
            rank_cache.prepare(inst);
            self.fill_cached(inst, tracker, rank_cache, order, out);
        } else {
            self.fill(inst, tracker, cands, ranked, order, out);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::us::validate_schedule;
    use crate::model::request::Request;
    use crate::model::server::{Server, ServerClass, ServerId};
    use crate::model::service::{CatalogParams, Placement, ServiceCatalog, ServiceId, TierId};
    use crate::model::topology::{Topology, TopologyParams};
    use crate::util::rng::Rng;

    fn small_instance(n_requests: usize, seed: u64) -> ProblemInstance<'static> {
        let mut rng = Rng::new(seed);
        let topology = Topology::paper_default(
            &TopologyParams { num_edge: 3, num_cloud: 1, ..Default::default() },
            &mut rng,
        );
        let catalog = ServiceCatalog::synthetic(
            &CatalogParams { num_services: 3, num_tiers: 4, ..Default::default() },
            &mut rng,
        );
        let placement = Placement::random(
            &catalog,
            &[
                ServerClass::EdgeSmall,
                ServerClass::EdgeMedium,
                ServerClass::EdgeLarge,
                ServerClass::Cloud,
            ],
            &mut rng,
        );
        let requests = (0..n_requests)
            .map(|i| {
                Request::new(i, i % 3, i % 3)
                    .with_qos(rng.uniform(30.0, 60.0), rng.uniform(1200.0, 6000.0))
                    .with_queue_delay(rng.uniform(0.0, 50.0))
            })
            .collect();
        ProblemInstance::new(topology, catalog, placement, requests)
    }

    #[test]
    fn produces_valid_strict_schedule() {
        let inst = small_instance(20, 1);
        let s = Gus::default().schedule(&inst, &mut Rng::new(0));
        validate_schedule(&inst, &s, ConstraintMode::STRICT).unwrap();
    }

    #[test]
    fn all_assignments_meet_qos() {
        let inst = small_instance(30, 2);
        let s = Gus::default().schedule(&inst, &mut Rng::new(0));
        assert_eq!(s.satisfied(&inst), s.served());
    }

    #[test]
    fn objective_nonnegative_under_strict_mode() {
        // QoS-feasible candidates always have US >= 0.
        let inst = small_instance(50, 3);
        let s = Gus::default().schedule(&inst, &mut Rng::new(0));
        assert!(s.objective() >= 0.0);
        for a in s.slots.iter().flatten() {
            assert!(a.us >= 0.0);
        }
    }

    #[test]
    fn deterministic() {
        let inst = small_instance(25, 4);
        let a = Gus::default().schedule(&inst, &mut Rng::new(0));
        let b = Gus::default().schedule(&inst, &mut Rng::new(99));
        for (x, y) in a.slots.iter().zip(b.slots.iter()) {
            assert_eq!(x.is_some(), y.is_some());
            if let (Some(x), Some(y)) = (x, y) {
                assert_eq!(x.candidate.server, y.candidate.server);
                assert_eq!(x.candidate.tier, y.candidate.tier);
            }
        }
    }

    #[test]
    fn picks_highest_us_when_capacity_allows() {
        let inst = small_instance(1, 5);
        let s = Gus::default().schedule(&inst, &mut Rng::new(0));
        let Some(a) = &s.slots[0] else { panic!("request should be served") };
        // No capacity pressure with a single request: must be the US-max
        // QoS-feasible candidate.
        let req = &inst.requests[0];
        let best = inst
            .candidates(0)
            .into_iter()
            .filter(|c| qos_satisfied(req, c))
            .map(|c| user_satisfaction(req, &c, inst.max_accuracy_pct, inst.max_completion_ms))
            .fold(f64::NEG_INFINITY, f64::max);
        assert!((a.us - best).abs() < 1e-12);
    }

    #[test]
    fn drops_unsatisfiable_requests() {
        let mut inst = small_instance(5, 6);
        for r in &mut inst.requests {
            r.min_accuracy_pct = 100.0; // nothing reaches 100% exactly
        }
        let s = Gus::default().schedule(&inst, &mut Rng::new(0));
        assert_eq!(s.served(), 0);
    }

    #[test]
    fn capacity_exhaustion_forces_drops_or_spill() {
        // One edge, no cloud: γ bounds how many can be served.
        let mut rng = Rng::new(7);
        let topology = Topology::explicit(
            vec![Server::new(0, ServerClass::EdgeSmall).with_capacities(2.0, 0.0)],
            vec![vec![0.0]],
        );
        let catalog = ServiceCatalog::synthetic(
            &CatalogParams { num_services: 1, num_tiers: 1, ..Default::default() },
            &mut rng,
        );
        let placement = Placement::full(&catalog, 1);
        let requests = (0..5)
            .map(|i| Request::new(i, 0, 0).with_qos(0.0, 10_000.0))
            .collect();
        let inst = ProblemInstance::new(topology, catalog, placement, requests);
        let s = Gus::default().schedule(&inst, &mut Rng::new(0));
        // comp_cost of tier 0 is 1.0, γ=2 → exactly 2 served.
        assert_eq!(s.served(), 2);
        validate_schedule(&inst, &s, ConstraintMode::STRICT).unwrap();
    }

    #[test]
    fn eta_constraint_blocks_offloading() {
        // Two servers; covering edge has η=0 → no offload possible.
        let mut rng = Rng::new(8);
        let topology = Topology::explicit(
            vec![
                Server::new(0, ServerClass::EdgeSmall).with_capacities(0.0, 0.0),
                Server::new(1, ServerClass::EdgeLarge).with_capacities(10.0, 10.0),
            ],
            vec![vec![0.0, 10.0], vec![10.0, 0.0]],
        );
        let catalog = ServiceCatalog::synthetic(
            &CatalogParams { num_services: 1, num_tiers: 1, ..Default::default() },
            &mut rng,
        );
        let placement = Placement::full(&catalog, 2);
        let requests = vec![Request::new(0, 0, 0).with_qos(0.0, 10_000.0)];
        let inst = ProblemInstance::new(topology, catalog, placement, requests);
        let strict = Gus::default().schedule(&inst, &mut Rng::new(0));
        assert_eq!(strict.served(), 0, "γ=0 locally and η=0 blocks offload");
        // Happy-Communication relaxes η and can offload.
        let happy = Gus::with_mode(ConstraintMode::HAPPY_COMMUNICATION)
            .schedule(&inst, &mut Rng::new(0));
        assert_eq!(happy.served(), 1);
        assert_eq!(happy.slots[0].as_ref().unwrap().candidate.server, ServerId(1));
    }

    #[test]
    fn priority_wins_contested_capacity() {
        // One server, γ=1, two identical requests: the high-priority one
        // must be served even though it is submitted second.
        let mut rng = Rng::new(10);
        let topology = Topology::explicit(
            vec![Server::new(0, ServerClass::EdgeMedium).with_capacities(1.0, 0.0)],
            vec![vec![0.0]],
        );
        let catalog = ServiceCatalog::synthetic(
            &CatalogParams { num_services: 1, num_tiers: 1, ..Default::default() },
            &mut rng,
        );
        let placement = Placement::full(&catalog, 1);
        let requests = vec![
            Request::new(0, 0, 0).with_qos(0.0, 10_000.0),
            Request::new(1, 0, 0).with_qos(0.0, 10_000.0).with_priority(5),
        ];
        let inst = ProblemInstance::new(topology, catalog, placement, requests);
        let s = Gus::default().schedule(&inst, &mut Rng::new(0));
        assert!(s.slots[0].is_none(), "best-effort request must yield");
        assert!(s.slots[1].is_some(), "priority request must be served");
    }

    #[test]
    fn cached_walk_matches_legacy_sort_bitwise() {
        // The rank cache is an optimization, not a policy change: every
        // slot (assignment and US value) must be bitwise identical.
        for seed in [1, 2, 3, 12, 13] {
            let inst = small_instance(40, seed);
            for mode in [
                ConstraintMode::STRICT,
                ConstraintMode::SOFT_QOS,
                ConstraintMode::HAPPY_COMPUTATION,
                ConstraintMode::HAPPY_COMMUNICATION,
            ] {
                let cached = Gus::with_mode(mode).schedule(&inst, &mut Rng::new(0));
                let legacy =
                    Gus::with_mode(mode).uncached().schedule(&inst, &mut Rng::new(0));
                for (i, (c, l)) in cached.slots.iter().zip(legacy.slots.iter()).enumerate() {
                    match (c, l) {
                        (None, None) => {}
                        (Some(c), Some(l)) => {
                            assert_eq!(c.candidate.server, l.candidate.server, "req {i}");
                            assert_eq!(c.candidate.tier, l.candidate.tier, "req {i}");
                            assert_eq!(c.us.to_bits(), l.us.to_bits(), "req {i}");
                            assert_eq!(
                                c.candidate.completion_ms.to_bits(),
                                l.candidate.completion_ms.to_bits(),
                                "req {i}"
                            );
                        }
                        (c, l) => panic!("seed {seed} req {i}: cached {c:?} vs legacy {l:?}"),
                    }
                }
            }
        }
    }

    #[test]
    fn warm_cache_stays_exact_across_world_mutations() {
        // Same scratch across frames while the world mutates between
        // them: the lazily invalidated cache must keep matching a cold
        // uncached run after every mutation.
        let mut inst = small_instance(30, 21);
        let cached = Gus::default();
        let legacy = Gus::default().uncached();
        let mut scratch = SchedScratch::default();
        let mut out = Schedule::empty(0);
        for frame in 0..6 {
            match frame {
                1 => inst.topology.to_mut().set_up(ServerId(1), false),
                2 => inst.topology.to_mut().set_comm_ms(ServerId(0), ServerId(2), 400.0),
                3 => inst.topology.to_mut().set_up(ServerId(1), true),
                4 => inst.placement.to_mut().place(2, ServiceId(1), TierId(0)),
                _ => {}
            }
            cached.schedule_into(&inst, &mut Rng::new(0), &mut scratch, &mut out);
            let fresh = legacy.schedule(&inst, &mut Rng::new(0));
            for (c, l) in out.slots.iter().zip(fresh.slots.iter()) {
                assert_eq!(
                    c.map(|a| (a.candidate.server, a.candidate.tier, a.us.to_bits())),
                    l.map(|a| (a.candidate.server, a.candidate.tier, a.us.to_bits())),
                    "frame {frame}"
                );
            }
        }
        assert!(scratch.rank_cache.hits > 0, "steady frames must hit the cache");
        assert!(scratch.rank_cache.misses > 0, "mutations must invalidate");
    }

    #[test]
    fn tie_break_prefers_local_then_lower_tier() {
        // Construct two candidates with identical US via identical
        // profiles; the local one must win.
        let mut rng = Rng::new(9);
        let topology = Topology::explicit(
            vec![
                Server::new(0, ServerClass::EdgeMedium).with_capacities(10.0, 10.0),
                Server::new(1, ServerClass::EdgeMedium).with_capacities(10.0, 10.0),
            ],
            vec![vec![0.0, 0.0], vec![0.0, 0.0]], // zero comm delay → equal US
        );
        let catalog = ServiceCatalog::synthetic(
            &CatalogParams { num_services: 1, num_tiers: 1, ..Default::default() },
            &mut rng,
        );
        let placement = Placement::full(&catalog, 2);
        let requests = vec![Request::new(0, 0, 0).with_qos(0.0, 100_000.0)];
        let mut inst = ProblemInstance::new(topology, catalog, placement, requests);
        inst = inst.with_normalization(100.0, 12_000.0);
        let s = Gus::default().schedule(&inst, &mut Rng::new(0));
        let a = s.slots[0].as_ref().unwrap();
        assert_eq!(a.candidate.server, ServerId(0), "local preferred on tie");
        assert_eq!(a.candidate.tier, TierId(0));
    }
}
