//! The five baseline heuristics of §IV.
//!
//! 1. **Random-Assignment** — pick a server uniformly at random; serve
//!    there iff some tier meets QoS and capacity, else drop.
//! 2. **Offload-All** — send everything to the cloud.
//! 3. **Local-All** — serve everything at the covering edge server.
//! 4. **Happy-Computation** — GUS with the computation constraint (2d)
//!    relaxed.
//! 5. **Happy-Communication** — GUS with the communication constraint
//!    (2e) relaxed.

use crate::coordinator::gus::Gus;
use crate::coordinator::us::{
    qos_satisfied, user_satisfaction, Assignment, CapacityTracker, ConstraintMode, Schedule,
};
use crate::coordinator::{SchedScratch, Scheduler};
use crate::model::instance::Candidate;
use crate::model::request::Request;
use crate::model::{ProblemInstance, ServerId};
use crate::util::rng::Rng;

/// Rank the QoS-feasible candidates for request `i` restricted to server
/// `j` into `ranked` (cleared first), best US first. `cands` is the
/// reusable enumeration buffer.
fn ranked_on_server_into(
    inst: &ProblemInstance,
    i: usize,
    server: ServerId,
    cands: &mut Vec<Candidate>,
    ranked: &mut Vec<(f64, Candidate)>,
) {
    ranked.clear();
    let req = &inst.requests[i];
    inst.candidates_into(i, cands);
    for &c in cands.iter() {
        if c.server == server && qos_satisfied(req, &c) {
            ranked.push((
                user_satisfaction(req, &c, inst.max_accuracy_pct, inst.max_completion_ms),
                c,
            ));
        }
    }
    ranked.sort_by(|a, b| b.0.total_cmp(&a.0));
}

fn try_assign(
    schedule: &mut Schedule,
    tracker: &mut CapacityTracker,
    req: &Request,
    i: usize,
    ranked: &[(f64, Candidate)],
) {
    for (us, cand) in ranked {
        if tracker.fits(req, cand) {
            tracker.commit(req, cand);
            schedule.slots[i] = Some(Assignment { request: req.id, candidate: *cand, us: *us });
            return;
        }
    }
}

/// Baseline 1: a uniformly random server; drop if it cannot satisfy.
pub struct RandomAssignment;

impl Scheduler for RandomAssignment {
    fn name(&self) -> &'static str {
        "random"
    }

    fn schedule_into(
        &self,
        inst: &ProblemInstance,
        rng: &mut Rng,
        scratch: &mut SchedScratch,
        out: &mut Schedule,
    ) {
        out.reset(inst.num_requests());
        let SchedScratch { cands, ranked, tracker, .. } = scratch;
        tracker.reset(inst, ConstraintMode::STRICT);
        for i in 0..inst.num_requests() {
            let req = &inst.requests[i];
            let server = ServerId(rng.index(inst.num_servers()));
            ranked_on_server_into(inst, i, server, cands, ranked);
            try_assign(out, tracker, req, i, ranked);
        }
    }
}

/// Baseline 2: offload everything to the cloud tier.
pub struct OffloadAll;

impl Scheduler for OffloadAll {
    fn name(&self) -> &'static str {
        "offload-all"
    }

    fn schedule_into(
        &self,
        inst: &ProblemInstance,
        _rng: &mut Rng,
        scratch: &mut SchedScratch,
        out: &mut Schedule,
    ) {
        out.reset(inst.num_requests());
        let SchedScratch { cands, ranked, ranked_tmp, tracker, .. } = scratch;
        tracker.reset(inst, ConstraintMode::STRICT);
        let clouds = inst.topology.cloud_ids();
        for i in 0..inst.num_requests() {
            let req = &inst.requests[i];
            // With several clouds, rank across all of them: concatenate
            // the per-cloud sorted runs, then stable-sort the whole —
            // the same tie order as the historical per-cloud extend.
            ranked.clear();
            for &c in &clouds {
                ranked_on_server_into(inst, i, c, cands, ranked_tmp);
                ranked.extend_from_slice(ranked_tmp);
            }
            ranked.sort_by(|a, b| b.0.total_cmp(&a.0));
            try_assign(out, tracker, req, i, ranked);
        }
    }
}

/// Baseline 3: serve everything at the covering edge server.
pub struct LocalAll;

impl Scheduler for LocalAll {
    fn name(&self) -> &'static str {
        "local-all"
    }

    fn schedule_into(
        &self,
        inst: &ProblemInstance,
        _rng: &mut Rng,
        scratch: &mut SchedScratch,
        out: &mut Schedule,
    ) {
        out.reset(inst.num_requests());
        let SchedScratch { cands, ranked, tracker, .. } = scratch;
        tracker.reset(inst, ConstraintMode::STRICT);
        for i in 0..inst.num_requests() {
            let req = &inst.requests[i];
            ranked_on_server_into(inst, i, req.covering, cands, ranked);
            try_assign(out, tracker, req, i, ranked);
        }
    }
}

/// Baseline 4: no computation limit (constraint 2d relaxed).
pub struct HappyComputation;

impl Scheduler for HappyComputation {
    fn name(&self) -> &'static str {
        "happy-computation"
    }

    fn schedule_into(
        &self,
        inst: &ProblemInstance,
        rng: &mut Rng,
        scratch: &mut SchedScratch,
        out: &mut Schedule,
    ) {
        Gus::with_mode(ConstraintMode::HAPPY_COMPUTATION).schedule_into(inst, rng, scratch, out)
    }
}

/// Baseline 5: no communication limit (constraint 2e relaxed).
pub struct HappyCommunication;

impl Scheduler for HappyCommunication {
    fn name(&self) -> &'static str {
        "happy-communication"
    }

    fn schedule_into(
        &self,
        inst: &ProblemInstance,
        rng: &mut Rng,
        scratch: &mut SchedScratch,
        out: &mut Schedule,
    ) {
        Gus::with_mode(ConstraintMode::HAPPY_COMMUNICATION).schedule_into(inst, rng, scratch, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::us::validate_schedule;
    use crate::model::service::{CatalogParams, Placement, ServiceCatalog};
    use crate::model::topology::{Topology, TopologyParams};

    fn instance(n: usize, seed: u64) -> ProblemInstance<'static> {
        let mut rng = Rng::new(seed);
        let topology = Topology::paper_default(
            &TopologyParams { num_edge: 4, num_cloud: 1, ..Default::default() },
            &mut rng,
        );
        let catalog = ServiceCatalog::synthetic(
            &CatalogParams { num_services: 5, num_tiers: 3, ..Default::default() },
            &mut rng,
        );
        let placement = Placement::full(&catalog, 5);
        let requests = (0..n)
            .map(|i| {
                Request::new(i, i % 5, i % 4)
                    .with_qos(rng.uniform(30.0, 60.0), rng.uniform(1500.0, 8000.0))
            })
            .collect();
        ProblemInstance::new(topology, catalog, placement, requests)
    }

    #[test]
    fn offload_all_uses_only_cloud() {
        let inst = instance(20, 1);
        let s = OffloadAll.schedule(&inst, &mut Rng::new(0));
        for a in s.slots.iter().flatten() {
            assert!(inst.topology.server(a.candidate.server).is_cloud());
        }
        validate_schedule(&inst, &s, ConstraintMode::STRICT).unwrap();
    }

    #[test]
    fn local_all_never_offloads() {
        let inst = instance(20, 2);
        let s = LocalAll.schedule(&inst, &mut Rng::new(0));
        for (i, a) in s.slots.iter().enumerate() {
            if let Some(a) = a {
                assert_eq!(a.candidate.server, inst.requests[i].covering);
                assert!(!a.candidate.offloaded);
            }
        }
        validate_schedule(&inst, &s, ConstraintMode::STRICT).unwrap();
    }

    #[test]
    fn random_is_valid_and_seed_dependent() {
        let inst = instance(30, 3);
        let a = RandomAssignment.schedule(&inst, &mut Rng::new(1));
        let b = RandomAssignment.schedule(&inst, &mut Rng::new(2));
        validate_schedule(&inst, &a, ConstraintMode::STRICT).unwrap();
        validate_schedule(&inst, &b, ConstraintMode::STRICT).unwrap();
        let servers = |s: &Schedule| {
            s.slots
                .iter()
                .map(|x| x.as_ref().map(|a| a.candidate.server.0))
                .collect::<Vec<_>>()
        };
        assert_ne!(servers(&a), servers(&b), "different seeds should differ");
    }

    #[test]
    fn happy_computation_never_violates_communication() {
        let inst = instance(40, 4);
        let s = HappyComputation.schedule(&inst, &mut Rng::new(0));
        validate_schedule(&inst, &s, ConstraintMode::HAPPY_COMPUTATION).unwrap();
    }

    #[test]
    fn happy_communication_never_violates_computation() {
        let inst = instance(40, 5);
        let s = HappyCommunication.schedule(&inst, &mut Rng::new(0));
        validate_schedule(&inst, &s, ConstraintMode::HAPPY_COMMUNICATION).unwrap();
    }

    #[test]
    fn happy_variants_serve_at_least_as_many_as_gus() {
        // Relaxing a constraint can only help the greedy.
        for seed in 1..6 {
            let inst = instance(60, seed);
            let gus = Gus::default().schedule(&inst, &mut Rng::new(0));
            let hc = HappyComputation.schedule(&inst, &mut Rng::new(0));
            let hm = HappyCommunication.schedule(&inst, &mut Rng::new(0));
            assert!(hc.served() >= gus.served());
            assert!(hm.served() >= gus.served());
        }
    }

    #[test]
    fn all_baselines_never_assign_infeasible_qos() {
        let inst = instance(30, 6);
        for sched in crate::coordinator::all_schedulers() {
            let s = sched.schedule(&inst, &mut Rng::new(0));
            assert_eq!(s.satisfied(&inst), s.served(), "{} assigned non-QoS", sched.name());
        }
    }
}
