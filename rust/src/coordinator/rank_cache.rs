//! Incremental candidate-ranking cache: takes GUS frames from sort-bound
//! to walk-bound.
//!
//! PR 3 made the DES decision loop allocation-free; the remaining
//! steady-state cost is algorithmic — GUS re-enumerates and re-sorts every
//! (server, tier) candidate for every request on every frame, the paper's
//! O(|N|·(|L||M|)²) bound. But between scenario events the *relative
//! order* of a request's candidates depends only on its rank class
//! `(covering server, service)`: the US difference between two candidates
//! of the same request cancels the request-specific terms
//! (`A_i`, `C_i`, `T^q_i`), leaving
//!
//! ```text
//! rank_key = w_a · a_kl / Max_as − w_c · (T^comm + T^proc) / Max_cs
//! ```
//!
//! which is a pure function of the class and the world. The cache keeps,
//! per class, the candidate list sorted by `rank_key` descending and lets
//! GUS walk it against the residual-capacity tracker — O(|L||M|) per
//! request, no per-request sort, no re-enumeration.
//!
//! ## Exactness
//!
//! The cached walk is not an approximation: it yields bitwise-identical
//! schedules to the legacy enumerate+sort path (the DES golden tests
//! compare `to_json` output byte for byte). Two mechanisms make that hold:
//!
//! 1. The walk recomputes each candidate's `completion_ms` as
//!    `T^q + T^comm + T^proc` with the same left-associated additions as
//!    [`ProblemInstance::completion_ms`], and scores it through the same
//!    [`user_satisfaction`]/[`qos_satisfied`] functions — so every float
//!    is the same bit pattern the legacy path produced.
//! 2. Legacy GUS takes the *first fitting* candidate of a stable sort
//!    under the total order T = (US desc, local-first, lower-tier-first,
//!    enumeration order). First-fit-of-a-stable-sort equals the T-maximum
//!    over all fitting candidates, and the walk computes exactly that
//!    maximum by exhaustive comparison under T. The `rank_key` order is
//!    only used to *early-exit*: once a fitting best exists, any later
//!    candidate with `rank_key < best_rank_key − 1e-9` provably loses on
//!    US (float error in the cancelled request terms is bounded well
//!    below 1e-12), so the scan stops. The early exit is gated on the
//!    request's weights bit-matching the weights the keys were built with
//!    (`w_a = w_c = 1`, the system-wide default); any other weights fall
//!    back to a full exact scan — still correct, just not shortcut.
//!
//! ## Invalidation
//!
//! Generation-based and lazy. [`Topology`] carries an up/down generation
//! and a per-source-row comm generation; [`Placement`] a per-service
//! generation — all stamped from one process-global counter
//! ([`crate::model::topology::next_world_gen`]), so freshly built worlds
//! (the serving leader rebuilds its topology every frame) can never alias
//! a stale entry. A class entry records the generations it was built
//! against and rebuilds in [`RankCache::prepare`] when any is stale;
//! QoS thresholds and queue delays cancel out of the ranking entirely, so
//! they are deliberately *not* part of the key. Rebuilds of many classes
//! (first frame, post-outage) fan out over [`crate::benchkit::parallel_map`].

use crate::coordinator::us::{qos_satisfied, user_satisfaction, CapacityTracker, ConstraintMode};
use crate::model::instance::Candidate;
use crate::model::request::Request;
use crate::model::server::ServerId;
use crate::model::service::{ServiceId, TierId};
use crate::model::ProblemInstance;

/// Weights the cached `rank_key`s are computed with. The early exit in
/// [`RankCache::walk_best`] is only sound for requests whose weights
/// bit-match these; others get a full (still exact) scan.
const RANK_W_ACCURACY: f64 = 1.0;
const RANK_W_COMPLETION: f64 = 1.0;

/// Early-exit margin on `rank_key` differences. US is recomputed exactly,
/// so this only has to dominate the float error of the *cancelled*
/// request-constant terms — bounded around 1e-13 for any sane world;
/// 1e-9 leaves four orders of magnitude of slack while costing at most a
/// handful of extra candidate visits per request.
const RANK_EPS: f64 = 1e-9;

/// Rebuilding at least this many stale classes in one `prepare` fans out
/// over `parallel_map`; below it, serial rebuild wins (scoped-thread
/// setup costs more than the sorts it saves).
const PARALLEL_REBUILD_THRESHOLD: usize = 16;

/// One pre-ranked candidate. Stores the completion time *split* into its
/// class-constant parts (`comm_ms`, `proc_ms`) so the walk can
/// reconstitute `completion_ms = T^q + T^comm + T^proc` bit-for-bit for
/// any queue delay.
#[derive(Clone, Copy, Debug)]
pub struct CachedCand {
    pub server: ServerId,
    pub tier: TierId,
    pub accuracy_pct: f64,
    /// Covering→server forwarding delay (0.0 exactly for local).
    pub comm_ms: f64,
    /// Processing delay at `server`'s class.
    pub proc_ms: f64,
    pub comp_cost: f64,
    pub comm_cost: f64,
    pub offloaded: bool,
    /// Class-constant part of US under the default weights; the sort key.
    pub rank_key: f64,
    /// Position in the legacy enumeration order — the final tie-breaker
    /// of the total order T.
    pub orig: u32,
}

/// One rank class: the ranked candidates plus the world generations and
/// normalization constants they were built against.
#[derive(Clone, Debug, Default)]
struct Entry {
    cands: Vec<CachedCand>,
    built: bool,
    /// Dedup flag while this class sits on the current stale list.
    queued: bool,
    up_gen: u64,
    comm_row_gen: u64,
    service_gen: u64,
    max_as: f64,
    max_cs: f64,
}

/// The per-scheduler incremental ranking cache. Lives inside
/// [`crate::coordinator::SchedScratch`], so the DES carries it warm
/// across frames while batch callers get a cold one per `schedule()`.
#[derive(Debug, Default)]
pub struct RankCache {
    /// Dense class table, indexed `covering · num_services + service`.
    entries: Vec<Entry>,
    num_servers: usize,
    num_services: usize,
    /// Scratch list of stale class indices, reused across frames.
    stale: Vec<usize>,
    /// Requests whose class entry was already fresh at frame start.
    pub hits: u64,
    /// Requests whose class entry had to be (re)built this frame.
    pub misses: u64,
    /// Class rebuilds performed (≤ misses: co-class requests share one).
    pub rebuilds: u64,
}

impl RankCache {
    /// Bring every class touched by `inst`'s requests up to date and
    /// account hits/misses. Called once per frame before the walks; this
    /// is the only allocating part of the cached path.
    pub fn prepare(&mut self, inst: &ProblemInstance) {
        let ns = inst.topology.len();
        let nk = inst.catalog.num_services;
        if self.num_servers != ns || self.num_services != nk {
            self.num_servers = ns;
            self.num_services = nk;
            self.entries.clear();
            self.entries.resize_with(ns * nk, Entry::default);
        }
        let up_gen = inst.topology.up_gen();
        self.stale.clear();
        for req in inst.requests.iter() {
            let class = req.covering.0 * nk + req.service.0;
            let e = &mut self.entries[class];
            let fresh = e.built
                && e.up_gen == up_gen
                && e.comm_row_gen == inst.topology.comm_row_gen(req.covering)
                && e.service_gen == inst.placement.service_gen(req.service)
                && e.max_as.to_bits() == inst.max_accuracy_pct.to_bits()
                && e.max_cs.to_bits() == inst.max_completion_ms.to_bits();
            if fresh {
                self.hits += 1;
            } else {
                self.misses += 1;
                if !e.queued {
                    e.queued = true;
                    self.stale.push(class);
                }
            }
        }
        if self.stale.is_empty() {
            return;
        }
        self.rebuilds += self.stale.len() as u64;
        if self.stale.len() >= PARALLEL_REBUILD_THRESHOLD {
            let threads = crate::sim::montecarlo::default_threads();
            let built: Vec<Vec<CachedCand>> =
                crate::benchkit::parallel_map(&self.stale, threads, |_, &class| {
                    let mut cands = Vec::new();
                    build_class_into(inst, ServerId(class / nk), ServiceId(class % nk), &mut cands);
                    cands
                });
            for (&class, cands) in self.stale.iter().zip(built) {
                let e = &mut self.entries[class];
                e.cands = cands;
                stamp_entry(e, inst, ServerId(class / nk), ServiceId(class % nk), up_gen);
            }
        } else {
            for &class in self.stale.iter() {
                let covering = ServerId(class / nk);
                let service = ServiceId(class % nk);
                let e = &mut self.entries[class];
                build_class_into(inst, covering, service, &mut e.cands);
                stamp_entry(e, inst, covering, service, up_gen);
            }
        }
    }

    // lint:no-alloc:begin — the steady-state cached walk: one pass over a
    // pre-ranked slice per request, no enumeration, no sort, no heap.
    /// Find the candidate legacy GUS would commit for `req`: the T-maximum
    /// (US desc, local-first, lower-tier-first, enumeration order) over
    /// all QoS-feasible candidates that fit the residual capacities.
    /// Returns the exact `(us, candidate)` the legacy path would produce,
    /// or `None` when the request must be dropped.
    ///
    /// [`RankCache::prepare`] must have run on this instance first.
    pub fn walk_best(
        &self,
        req: &Request,
        mode: ConstraintMode,
        max_as: f64,
        max_cs: f64,
        tracker: &CapacityTracker,
    ) -> Option<(f64, Candidate)> {
        let entry = &self.entries[req.covering.0 * self.num_services + req.service.0];
        debug_assert!(entry.built, "walk_best before prepare");
        let keyed = req.w_accuracy.to_bits() == RANK_W_ACCURACY.to_bits()
            && req.w_completion.to_bits() == RANK_W_COMPLETION.to_bits();
        let mut best: Option<(f64, Candidate, u32, f64)> = None;
        for cc in entry.cands.iter() {
            if let Some((_, _, _, best_key)) = best {
                if keyed && cc.rank_key < best_key - RANK_EPS {
                    break;
                }
            }
            let cand = Candidate {
                server: cc.server,
                tier: cc.tier,
                accuracy_pct: cc.accuracy_pct,
                completion_ms: req.queue_delay_ms + cc.comm_ms + cc.proc_ms,
                comp_cost: cc.comp_cost,
                comm_cost: cc.comm_cost,
                offloaded: cc.offloaded,
            };
            if mode.qos && !qos_satisfied(req, &cand) {
                continue;
            }
            let us = user_satisfaction(req, &cand, max_as, max_cs);
            if !mode.qos && us < 0.0 {
                continue;
            }
            if !tracker.fits(req, &cand) {
                continue;
            }
            let wins = match &best {
                None => true,
                // Strictly-greater under T: higher US, then local over
                // offloaded, then lower tier, then earlier enumeration.
                Some((best_us, best_cand, best_orig, _)) => us
                    .total_cmp(best_us)
                    .then_with(|| best_cand.offloaded.cmp(&cand.offloaded))
                    .then_with(|| best_cand.tier.cmp(&cand.tier))
                    .then_with(|| best_orig.cmp(&cc.orig))
                    .is_gt(),
            };
            if wins {
                best = Some((us, cand, cc.orig, cc.rank_key));
            }
        }
        best.map(|(us, cand, _, _)| (us, cand))
    }
    // lint:no-alloc:end

    /// Ranked candidates currently cached for one class, or `None` if the
    /// class is out of range or was never built. Test/bench oracle access.
    pub fn ranked_class(&self, covering: ServerId, service: ServiceId) -> Option<&[CachedCand]> {
        if covering.0 >= self.num_servers || service.0 >= self.num_services {
            return None;
        }
        let e = &self.entries[covering.0 * self.num_services + service.0];
        if e.built {
            Some(&e.cands)
        } else {
            None
        }
    }

    /// Number of classes with a built entry.
    pub fn built_classes(&self) -> usize {
        self.entries.iter().filter(|e| e.built).count()
    }

    /// Warm fraction of all class lookups so far (0.0 before any lookup).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Record the world generations and normalization constants a just-built
/// entry is valid against.
fn stamp_entry(
    e: &mut Entry,
    inst: &ProblemInstance,
    covering: ServerId,
    service: ServiceId,
    up_gen: u64,
) {
    e.built = true;
    e.queued = false;
    e.up_gen = up_gen;
    e.comm_row_gen = inst.topology.comm_row_gen(covering);
    e.service_gen = inst.placement.service_gen(service);
    e.max_as = inst.max_accuracy_pct;
    e.max_cs = inst.max_completion_ms;
}

/// Rebuild one class: mirror [`ProblemInstance::candidates_into`]'s
/// enumeration exactly (servers ascending, down servers skipped, placed
/// tiers in placement order), then rank by `rank_key` descending with the
/// enumeration index as tie-breaker.
fn build_class_into(
    inst: &ProblemInstance,
    covering: ServerId,
    service: ServiceId,
    out: &mut Vec<CachedCand>,
) {
    out.clear();
    let max_as = inst.max_accuracy_pct;
    let max_cs = inst.max_completion_ms;
    let mut orig: u32 = 0;
    for j in 0..inst.topology.len() {
        if !inst.topology.servers[j].up {
            continue;
        }
        let server = ServerId(j);
        let class_idx = inst.topology.server(server).class.index();
        let comm_ms = if server == covering {
            0.0
        } else {
            inst.topology.comm_ms(covering, server)
        };
        inst.placement
            .for_each_tier(j, service, inst.catalog.num_tiers, |tier| {
                let profile = inst.catalog.profile(service, tier);
                let proc_ms = profile.proc_ms[class_idx];
                out.push(CachedCand {
                    server,
                    tier,
                    accuracy_pct: profile.accuracy_pct,
                    comm_ms,
                    proc_ms,
                    comp_cost: profile.comp_cost,
                    comm_cost: profile.comm_cost,
                    offloaded: server != covering,
                    rank_key: RANK_W_ACCURACY * profile.accuracy_pct / max_as
                        - RANK_W_COMPLETION * (comm_ms + proc_ms) / max_cs,
                    orig,
                });
                orig += 1;
            });
    }
    // `sort_unstable` is safe despite the legacy path using a stable
    // sort: `orig` makes the comparator a total order with no ties.
    out.sort_unstable_by(|a, b| {
        b.rank_key.total_cmp(&a.rank_key).then_with(|| a.orig.cmp(&b.orig))
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::server::ServerClass;
    use crate::model::service::{CatalogParams, Placement, ServiceCatalog};
    use crate::model::topology::{Topology, TopologyParams};
    use crate::util::rng::Rng;

    fn world(seed: u64) -> (Topology, ServiceCatalog, Placement) {
        let mut rng = Rng::new(seed);
        let topology = Topology::paper_default(
            &TopologyParams { num_edge: 3, num_cloud: 1, ..Default::default() },
            &mut rng,
        );
        let catalog = ServiceCatalog::synthetic(
            &CatalogParams { num_services: 4, num_tiers: 3, ..Default::default() },
            &mut rng,
        );
        let classes: Vec<ServerClass> = topology.servers.iter().map(|s| s.class).collect();
        let placement = Placement::random(&catalog, &classes, &mut rng);
        (topology, catalog, placement)
    }

    fn requests(n: usize, seed: u64) -> Vec<Request> {
        let mut rng = Rng::new(seed ^ 0xbeef);
        (0..n)
            .map(|i| {
                Request::new(i, i % 4, i % 3)
                    .with_qos(rng.uniform(30.0, 60.0), rng.uniform(1200.0, 8000.0))
                    .with_queue_delay(rng.uniform(0.0, 500.0))
            })
            .collect()
    }

    /// The legacy path for one request: enumerate, filter, stable-sort,
    /// first fit. Mirrors `Gus::fill` exactly.
    fn legacy_best(
        inst: &ProblemInstance,
        i: usize,
        mode: ConstraintMode,
        tracker: &CapacityTracker,
    ) -> Option<(f64, Candidate)> {
        let req = &inst.requests[i];
        let mut ranked: Vec<(f64, Candidate)> = Vec::new();
        for cand in inst.candidates(i) {
            if mode.qos && !qos_satisfied(req, &cand) {
                continue;
            }
            let us = user_satisfaction(req, &cand, inst.max_accuracy_pct, inst.max_completion_ms);
            if !mode.qos && us < 0.0 {
                continue;
            }
            ranked.push((us, cand));
        }
        ranked.sort_by(|a, b| {
            b.0.total_cmp(&a.0)
                .then_with(|| a.1.offloaded.cmp(&b.1.offloaded))
                .then_with(|| a.1.tier.cmp(&b.1.tier))
        });
        ranked.into_iter().find(|(_, c)| tracker.fits(req, c))
    }

    fn assert_same(a: Option<(f64, Candidate)>, b: Option<(f64, Candidate)>, ctx: &str) {
        match (a, b) {
            (None, None) => {}
            (Some((ua, ca)), Some((ub, cb))) => {
                assert_eq!(ua.to_bits(), ub.to_bits(), "{ctx}: us differs");
                assert_eq!(ca.server, cb.server, "{ctx}: server differs");
                assert_eq!(ca.tier, cb.tier, "{ctx}: tier differs");
                assert_eq!(
                    ca.completion_ms.to_bits(),
                    cb.completion_ms.to_bits(),
                    "{ctx}: completion differs"
                );
            }
            (a, b) => panic!("{ctx}: walk {a:?} vs legacy {b:?}"),
        }
    }

    #[test]
    fn walk_matches_legacy_for_every_mode_and_seed() {
        for seed in [1, 2, 7, 11] {
            let (topology, catalog, placement) = world(seed);
            let inst =
                ProblemInstance::new(topology, catalog, placement, requests(40, seed))
                    .with_normalization(100.0, 12_000.0);
            for mode in [
                ConstraintMode::STRICT,
                ConstraintMode::SOFT_QOS,
                ConstraintMode::HAPPY_COMPUTATION,
                ConstraintMode::HAPPY_COMMUNICATION,
            ] {
                let mut cache = RankCache::default();
                cache.prepare(&inst);
                // Walk with a *consuming* tracker so later requests see
                // contested capacity, like a real frame.
                let mut tracker = CapacityTracker::new(&inst, mode);
                for i in 0..inst.num_requests() {
                    let legacy = legacy_best(&inst, i, mode, &tracker);
                    let walked = cache.walk_best(
                        &inst.requests[i],
                        mode,
                        inst.max_accuracy_pct,
                        inst.max_completion_ms,
                        &tracker,
                    );
                    assert_same(walked, legacy, &format!("seed {seed} req {i}"));
                    if let Some((_, cand)) = walked {
                        tracker.commit(&inst.requests[i], &cand);
                    }
                }
            }
        }
    }

    #[test]
    fn non_default_weights_fall_back_to_exact_full_scan() {
        let (topology, catalog, placement) = world(3);
        let reqs: Vec<Request> = requests(20, 3)
            .into_iter()
            .map(|r| r.with_weights(0.3, 1.7))
            .collect();
        let inst = ProblemInstance::new(topology, catalog, placement, reqs)
            .with_normalization(100.0, 12_000.0);
        let mut cache = RankCache::default();
        cache.prepare(&inst);
        let tracker = CapacityTracker::new(&inst, ConstraintMode::STRICT);
        for i in 0..inst.num_requests() {
            let legacy = legacy_best(&inst, i, ConstraintMode::STRICT, &tracker);
            let walked = cache.walk_best(
                &inst.requests[i],
                ConstraintMode::STRICT,
                inst.max_accuracy_pct,
                inst.max_completion_ms,
                &tracker,
            );
            assert_same(walked, legacy, &format!("weighted req {i}"));
        }
    }

    #[test]
    fn second_prepare_is_all_hits() {
        let (topology, catalog, placement) = world(4);
        let inst = ProblemInstance::new(topology, catalog, placement, requests(30, 4));
        let mut cache = RankCache::default();
        cache.prepare(&inst);
        assert_eq!(cache.hits, 0);
        assert_eq!(cache.misses, 30);
        assert!(cache.rebuilds <= 30, "co-class requests share rebuilds");
        let rebuilds = cache.rebuilds;
        cache.prepare(&inst);
        assert_eq!(cache.hits, 30);
        assert_eq!(cache.misses, 30);
        assert_eq!(cache.rebuilds, rebuilds, "warm frame rebuilds nothing");
        assert!(cache.hit_rate() > 0.49 && cache.hit_rate() < 0.51);
    }

    #[test]
    fn mutations_invalidate_exactly_the_affected_classes() {
        let (mut topology, catalog, mut placement) = world(5);
        let reqs = requests(30, 5);
        {
            let inst = ProblemInstance::borrowed(&topology, &catalog, &placement, reqs.clone());
            let mut cache = RankCache::default();
            cache.prepare(&inst);
            drop(inst);
            // Comm drift on covering row 0: only classes covered by 0 miss.
            topology.set_comm_ms(ServerId(0), ServerId(2), 123.0);
            let inst = ProblemInstance::borrowed(&topology, &catalog, &placement, reqs.clone());
            let (h0, m0) = (cache.hits, cache.misses);
            cache.prepare(&inst);
            let covered_by_0 = reqs.iter().filter(|r| r.covering == ServerId(0)).count() as u64;
            assert_eq!(cache.misses - m0, covered_by_0);
            assert_eq!(cache.hits - h0, 30 - covered_by_0);
        }
        {
            // Placement change on service 1: only service-1 classes miss.
            let mut cache = RankCache::default();
            let inst = ProblemInstance::borrowed(&topology, &catalog, &placement, reqs.clone());
            cache.prepare(&inst);
            drop(inst);
            placement.place(0, ServiceId(1), TierId(0));
            let inst = ProblemInstance::borrowed(&topology, &catalog, &placement, reqs.clone());
            let (h0, m0) = (cache.hits, cache.misses);
            cache.prepare(&inst);
            let svc1 = reqs.iter().filter(|r| r.service == ServiceId(1)).count() as u64;
            assert_eq!(cache.misses - m0, svc1);
            assert_eq!(cache.hits - h0, 30 - svc1);
        }
        {
            // Outage: every class misses (up_gen is global).
            let mut cache = RankCache::default();
            let inst = ProblemInstance::borrowed(&topology, &catalog, &placement, reqs.clone());
            cache.prepare(&inst);
            drop(inst);
            topology.set_up(ServerId(1), false);
            let inst = ProblemInstance::borrowed(&topology, &catalog, &placement, reqs.clone());
            let m0 = cache.misses;
            cache.prepare(&inst);
            assert_eq!(cache.misses - m0, 30);
            // And the rebuilt entries exclude the down server.
            for r in reqs.iter().take(5) {
                let ranked = cache.ranked_class(r.covering, r.service).unwrap();
                assert!(ranked.iter().all(|c| c.server != ServerId(1)));
            }
        }
    }

    #[test]
    fn ranked_class_is_sorted_and_mirrors_enumeration() {
        let (topology, catalog, placement) = world(6);
        let reqs = requests(12, 6);
        let inst = ProblemInstance::new(topology, catalog, placement, reqs)
            .with_normalization(100.0, 12_000.0);
        let mut cache = RankCache::default();
        cache.prepare(&inst);
        for i in 0..inst.num_requests() {
            let req = &inst.requests[i];
            let ranked = cache.ranked_class(req.covering, req.service).unwrap();
            // Descending rank key.
            for w in ranked.windows(2) {
                assert!(w[0].rank_key >= w[1].rank_key);
            }
            // Content == legacy enumeration, item for item, via `orig`.
            let legacy = inst.candidates(i);
            assert_eq!(ranked.len(), legacy.len());
            let mut by_orig: Vec<&CachedCand> = ranked.iter().collect();
            by_orig.sort_by_key(|c| c.orig);
            for (cc, lc) in by_orig.iter().zip(legacy.iter()) {
                assert_eq!(cc.server, lc.server);
                assert_eq!(cc.tier, lc.tier);
                assert_eq!(cc.accuracy_pct.to_bits(), lc.accuracy_pct.to_bits());
                assert_eq!(
                    (req.queue_delay_ms + cc.comm_ms + cc.proc_ms).to_bits(),
                    lc.completion_ms.to_bits(),
                    "completion split must reconstitute bit-exactly"
                );
                assert_eq!(cc.offloaded, lc.offloaded);
            }
        }
    }

    #[test]
    fn parallel_rebuild_matches_serial() {
        // 9 edges × 4 services > threshold → parallel path; compare
        // against a cache forced through the serial path class by class.
        let mut rng = Rng::new(8);
        let topology =
            Topology::paper_default(&TopologyParams::default(), &mut rng);
        let catalog = ServiceCatalog::synthetic(
            &CatalogParams { num_services: 4, num_tiers: 3, ..Default::default() },
            &mut rng,
        );
        let classes: Vec<ServerClass> = topology.servers.iter().map(|s| s.class).collect();
        let placement = Placement::random(&catalog, &classes, &mut rng);
        let all_reqs: Vec<Request> = (0..36)
            .map(|i| Request::new(i, i % 4, i % 9).with_qos(20.0, 9000.0))
            .collect();
        assert!(all_reqs.len() >= PARALLEL_REBUILD_THRESHOLD);
        let inst =
            ProblemInstance::new(topology, catalog, placement, all_reqs.clone());
        let mut par = RankCache::default();
        par.prepare(&inst); // 36 distinct classes → parallel
        assert_eq!(par.rebuilds, 36);
        for chunk in all_reqs.chunks(4) {
            // ≤ 4 stale classes per prepare → serial.
            let mut ser = RankCache::default();
            let sub = ProblemInstance::borrowed(
                &inst.topology,
                &inst.catalog,
                &inst.placement,
                chunk.to_vec(),
            );
            ser.prepare(&sub);
            for r in chunk {
                let a = par.ranked_class(r.covering, r.service).unwrap();
                let b = ser.ranked_class(r.covering, r.service).unwrap();
                assert_eq!(a.len(), b.len());
                for (x, y) in a.iter().zip(b.iter()) {
                    assert_eq!(x.orig, y.orig);
                    assert_eq!(x.rank_key.to_bits(), y.rank_key.to_bits());
                }
            }
        }
    }
}
