//! The paper's L3 contribution: scheduling/offloading decisions that
//! maximize user satisfaction (the MUS problem).
//!
//! * [`us`] — the User-Satisfaction metric (Def. II.1), schedules,
//!   capacity tracking, and schedule validation (the ILP constraints);
//! * [`gus`] — the proposed greedy GUS algorithm (Algorithm 1);
//! * [`baselines`] — the five comparison heuristics from §IV;
//! * [`ilp`] — an exact branch-and-bound solver standing in for CPLEX
//!   (see DESIGN.md §Substitutions);
//! * [`explain`] — post-hoc schedule explanation: per-request drop
//!   reasons and candidate counts for any policy's output.

pub mod baselines;
pub mod explain;
pub mod gus;
pub mod ilp;
pub mod rank_cache;
pub mod us;

use crate::model::{Candidate, ProblemInstance};
use crate::util::rng::Rng;
pub use us::{Assignment, CapacityTracker, ConstraintMode, Schedule};

/// Reusable scheduler working memory. The DES owns one of these for the
/// whole run and hands it to [`Scheduler::schedule_into`] every frame,
/// so the steady-state decision loop performs no heap allocation: the
/// candidate buffer, ranking buffers, priority order, and capacity
/// tracker all retain their capacity across frames.
#[derive(Default)]
pub struct SchedScratch {
    /// Per-request candidate enumeration buffer.
    pub cands: Vec<Candidate>,
    /// (user-satisfaction, candidate) ranking buffer.
    pub ranked: Vec<(f64, Candidate)>,
    /// Secondary ranking buffer (Offload-All merges per-cloud runs).
    pub ranked_tmp: Vec<(f64, Candidate)>,
    /// Request indices in scheduling (priority) order.
    pub order: Vec<usize>,
    /// Residual-capacity tracker, refilled from the instance per call.
    pub tracker: CapacityTracker,
    /// Incremental candidate-ranking cache (GUS and the Happy-*
    /// baselines); entries survive across frames and invalidate lazily
    /// via world generation counters.
    pub rank_cache: rank_cache::RankCache,
}

/// A scheduling policy: produces a full [`Schedule`] for one decision
/// frame. `rng` makes stochastic policies (Random-Assignment) and
/// tie-breaking reproducible.
pub trait Scheduler {
    fn name(&self) -> &'static str;

    /// Allocation-free entry point: write the schedule for `inst` into
    /// `out` (resized to `inst.num_requests()`), using `scratch` for all
    /// working memory. Implementations must fully reset both — callers
    /// pass them warm from the previous frame.
    fn schedule_into(
        &self,
        inst: &ProblemInstance,
        rng: &mut Rng,
        scratch: &mut SchedScratch,
        out: &mut Schedule,
    );

    /// Convenience wrapper that allocates fresh scratch and schedule;
    /// batch callers (figures, Monte-Carlo, tests) use this.
    fn schedule(&self, inst: &ProblemInstance, rng: &mut Rng) -> Schedule {
        let mut scratch = SchedScratch::default();
        let mut out = Schedule::empty(inst.num_requests());
        self.schedule_into(inst, rng, &mut scratch, &mut out);
        out
    }
}

/// Every scheduler the evaluation compares, in the paper's order.
///
/// Three registry-only entries are deliberately excluded (reachable by
/// name through [`scheduler_by_name`] but not part of the six-policy
/// sweep):
///
/// * `ilp` — the exact branch-and-bound is exponential in the worst case;
///   it anchors the small-instance optimal-gap study but would dominate
///   (or time out) every Monte-Carlo/DES sweep point;
/// * `gus-soft` — the paper's §II "special case" treats the QoS
///   thresholds as suggestions, i.e. it optimizes a different feasibility
///   notion, so averaging it into the strict-QoS comparison would be
///   apples-to-oranges. The ablations bench compares it explicitly;
/// * `gus-nocache` — GUS with the incremental rank cache disabled:
///   byte-identical schedules to `gus`, kept only as the A/B oracle for
///   the cache (golden tests, `des_hot_path` bench). Sweeping it would
///   double-count the same policy.
pub fn all_schedulers() -> Vec<Box<dyn Scheduler + Send + Sync>> {
    vec![
        Box::new(gus::Gus::default()),
        Box::new(baselines::RandomAssignment),
        Box::new(baselines::OffloadAll),
        Box::new(baselines::LocalAll),
        Box::new(baselines::HappyComputation),
        Box::new(baselines::HappyCommunication),
    ]
}

/// Look a scheduler up by CLI name.
pub fn scheduler_by_name(name: &str) -> Option<Box<dyn Scheduler + Send + Sync>> {
    match name {
        "gus" => Some(Box::new(gus::Gus::default())),
        "random" => Some(Box::new(baselines::RandomAssignment)),
        "offload-all" | "offload_all" => Some(Box::new(baselines::OffloadAll)),
        "local-all" | "local_all" => Some(Box::new(baselines::LocalAll)),
        "happy-computation" | "happy_computation" => Some(Box::new(baselines::HappyComputation)),
        "happy-communication" | "happy_communication" => {
            Some(Box::new(baselines::HappyCommunication))
        }
        "gus-soft" | "gus_soft" => {
            Some(Box::new(gus::Gus::with_mode(ConstraintMode::SOFT_QOS)))
        }
        // Legacy enumerate+sort GUS with the rank cache disabled. A/B
        // oracle for the cache (des_hot_path bench, golden equivalence
        // tests); produces byte-identical output to `gus`.
        "gus-nocache" | "gus_nocache" => Some(Box::new(gus::Gus::default().uncached())),
        "ilp" | "optimal" => Some(Box::new(ilp::BranchAndBound::default())),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_has_six_policies() {
        assert_eq!(all_schedulers().len(), 6);
    }

    #[test]
    fn lookup_by_name() {
        for n in [
            "gus",
            "random",
            "offload-all",
            "local-all",
            "happy-computation",
            "happy-communication",
            "gus-soft",
            "gus-nocache",
            "ilp",
        ] {
            assert!(scheduler_by_name(n).is_some(), "{n} missing");
        }
        assert!(scheduler_by_name("nope").is_none());
    }

    #[test]
    fn registry_only_entries_not_in_sweep_set() {
        // `ilp` and `gus-soft` are lookup-only (see `all_schedulers` docs).
        let sweep: Vec<&str> = all_schedulers().iter().map(|s| s.name()).collect();
        assert!(!sweep.contains(&"ilp"));
        for name in &sweep {
            assert!(scheduler_by_name(name).is_some(), "{name} must be look-up-able");
        }
    }
}
