//! User Satisfaction (Def. II.1), schedules, residual-capacity tracking,
//! and full validation of the MUS ILP constraints (2a)–(2f).

use crate::model::instance::Candidate;
use crate::model::request::{Request, RequestId};
use crate::model::ProblemInstance;

/// `US_ijkl = w_a (a - A_i)/Max_as + w_c (C_i - c)/Max_cs` — Eq. (1).
///
/// Positive for any candidate meeting both QoS thresholds; may be negative
/// in the paper's "special case" where the thresholds are suggestions.
#[inline]
pub fn user_satisfaction(req: &Request, cand: &Candidate, max_as: f64, max_cs: f64) -> f64 {
    req.w_accuracy * (cand.accuracy_pct - req.min_accuracy_pct) / max_as
        + req.w_completion * (req.max_completion_ms - cand.completion_ms) / max_cs
}

/// Hard QoS feasibility: constraints (2b) and (2c).
#[inline]
pub fn qos_satisfied(req: &Request, cand: &Candidate) -> bool {
    cand.accuracy_pct >= req.min_accuracy_pct && cand.completion_ms <= req.max_completion_ms
}

/// Which capacity constraints a policy enforces — the Happy-* baselines
/// relax one each (§IV).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ConstraintMode {
    /// Enforce computation capacity (2d).
    pub computation: bool,
    /// Enforce communication capacity (2e).
    pub communication: bool,
    /// Enforce the QoS thresholds (2b)/(2c) as hard constraints; false is
    /// the paper's relaxed "special case".
    pub qos: bool,
}

impl ConstraintMode {
    pub const STRICT: ConstraintMode =
        ConstraintMode { computation: true, communication: true, qos: true };
    pub const HAPPY_COMPUTATION: ConstraintMode =
        ConstraintMode { computation: false, communication: true, qos: true };
    pub const HAPPY_COMMUNICATION: ConstraintMode =
        ConstraintMode { computation: true, communication: false, qos: true };
    /// The paper's §II "special case": QoS thresholds are suggestions,
    /// not hard constraints (2b)/(2c) relaxed; capacities still bind.
    pub const SOFT_QOS: ConstraintMode =
        ConstraintMode { computation: true, communication: true, qos: false };
}

/// One committed decision: request i → (server j, tier l).
#[derive(Clone, Copy, Debug)]
pub struct Assignment {
    pub request: RequestId,
    pub candidate: Candidate,
    /// Cached US of this assignment.
    pub us: f64,
}

/// Where a request ended up — drives Fig. 1(f)–(h).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DecisionKind {
    Local,
    OffloadCloud,
    OffloadPeer,
    Dropped,
}

/// A complete decision vector for one frame: `slots[i]` is request i's
/// assignment, `None` = dropped (constraint 2a allows ≤ 1 assignment).
#[derive(Clone, Debug)]
pub struct Schedule {
    pub slots: Vec<Option<Assignment>>,
}

impl Schedule {
    pub fn empty(n: usize) -> Schedule {
        Schedule { slots: vec![None; n] }
    }

    /// Clear and resize to `n` empty slots, keeping the allocation. The
    /// DES reuses one `Schedule` across all frames through this.
    pub fn reset(&mut self, n: usize) {
        self.slots.clear();
        self.slots.resize(n, None);
    }

    pub fn served(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn dropped(&self) -> usize {
        self.slots.len() - self.served()
    }

    /// The MUS objective (Eq. 2): mean US over all requests (dropped
    /// requests contribute 0).
    pub fn objective(&self) -> f64 {
        if self.slots.is_empty() {
            return 0.0;
        }
        self.slots
            .iter()
            .flatten()
            .map(|a| a.us)
            .sum::<f64>()
            / self.slots.len() as f64
    }

    /// Requests whose assignment meets both QoS thresholds.
    pub fn satisfied(&self, inst: &ProblemInstance) -> usize {
        self.slots
            .iter()
            .flatten()
            .filter(|a| qos_satisfied(&inst.requests[a.request.0], &a.candidate))
            .count()
    }

    pub fn satisfied_pct(&self, inst: &ProblemInstance) -> f64 {
        if self.slots.is_empty() {
            return 0.0;
        }
        100.0 * self.satisfied(inst) as f64 / self.slots.len() as f64
    }

    pub fn kind(&self, i: usize, inst: &ProblemInstance) -> DecisionKind {
        match &self.slots[i] {
            None => DecisionKind::Dropped,
            Some(a) => {
                if !a.candidate.offloaded {
                    DecisionKind::Local
                } else if inst.topology.server(a.candidate.server).is_cloud() {
                    DecisionKind::OffloadCloud
                } else {
                    DecisionKind::OffloadPeer
                }
            }
        }
    }

    /// Decision mix in percent of all requests: (local, cloud, peer, drop).
    pub fn decision_mix_pct(&self, inst: &ProblemInstance) -> [f64; 4] {
        let n = self.slots.len().max(1) as f64;
        let mut counts = [0usize; 4];
        for i in 0..self.slots.len() {
            let idx = match self.kind(i, inst) {
                DecisionKind::Local => 0,
                DecisionKind::OffloadCloud => 1,
                DecisionKind::OffloadPeer => 2,
                DecisionKind::Dropped => 3,
            };
            counts[idx] += 1;
        }
        [
            100.0 * counts[0] as f64 / n,
            100.0 * counts[1] as f64 / n,
            100.0 * counts[2] as f64 / n,
            100.0 * counts[3] as f64 / n,
        ]
    }
}

/// Residual γ/η tracking while a schedule is being built; mirrors the
/// "update remaining capacity" steps of Algorithm 1.
#[derive(Clone, Debug)]
pub struct CapacityTracker {
    pub gamma: Vec<f64>,
    pub eta: Vec<f64>,
    /// Availability snapshot: a down covering edge cannot forward
    /// offloads, even under the Happy-Communication relaxation (the
    /// relaxation drops the η *budget*, not the physical link).
    up: Vec<bool>,
    mode: ConstraintMode,
}

impl Default for CapacityTracker {
    /// An empty tracker; must be [`CapacityTracker::reset`] against an
    /// instance before use. Exists so `SchedScratch` can pool one.
    fn default() -> CapacityTracker {
        CapacityTracker {
            gamma: Vec::new(),
            eta: Vec::new(),
            up: Vec::new(),
            mode: ConstraintMode::STRICT,
        }
    }
}

impl CapacityTracker {
    /// Down servers (scenario outages) contribute zero γ and zero η —
    /// even the Happy-* relaxations cannot route work through them, and
    /// a down covering edge cannot forward offloads.
    pub fn new(inst: &ProblemInstance, mode: ConstraintMode) -> CapacityTracker {
        let mut tracker = CapacityTracker::default();
        tracker.reset(inst, mode);
        tracker
    }

    /// Refill from `inst` without reallocating: clears and re-pushes into
    /// the retained buffers. Capacities come from the instance accessors,
    /// so a DES frame's residual γ is honored transparently.
    pub fn reset(&mut self, inst: &ProblemInstance, mode: ConstraintMode) {
        self.mode = mode;
        self.gamma.clear();
        self.eta.clear();
        self.up.clear();
        for (j, s) in inst.topology.servers.iter().enumerate() {
            self.gamma.push(if s.up { inst.gamma(j) } else { 0.0 });
            self.eta.push(if s.up { inst.eta(j) } else { 0.0 });
            self.up.push(s.up);
        }
    }

    /// Would serving `req` via `cand` fit the residual capacities?
    /// Computation (2d) is charged at the serving server; communication
    /// (2e) at the covering server, only when offloading. A down covering
    /// edge blocks offloading unconditionally — no mode relaxes a dead
    /// link.
    pub fn fits(&self, req: &Request, cand: &Candidate) -> bool {
        if cand.offloaded && !self.up[req.covering.0] {
            return false;
        }
        if self.mode.computation && self.gamma[cand.server.0] < cand.comp_cost - 1e-12 {
            return false;
        }
        if self.mode.communication
            && cand.offloaded
            && self.eta[req.covering.0] < cand.comm_cost - 1e-12
        {
            return false;
        }
        true
    }

    /// Commit the assignment, consuming capacity.
    pub fn commit(&mut self, req: &Request, cand: &Candidate) {
        debug_assert!(self.fits(req, cand));
        self.gamma[cand.server.0] -= cand.comp_cost;
        if cand.offloaded {
            self.eta[req.covering.0] -= cand.comm_cost;
        }
    }

    /// Release a previously committed assignment (used by B&B backtracking).
    pub fn release(&mut self, req: &Request, cand: &Candidate) {
        self.gamma[cand.server.0] += cand.comp_cost;
        if cand.offloaded {
            self.eta[req.covering.0] += cand.comm_cost;
        }
    }
}

/// Full check of the ILP constraints (2a)–(2f) over a finished schedule.
/// `mode` mirrors what the producing policy was allowed to relax.
pub fn validate_schedule(
    inst: &ProblemInstance,
    schedule: &Schedule,
    mode: ConstraintMode,
) -> Result<(), String> {
    if schedule.slots.len() != inst.num_requests() {
        return Err(format!(
            "schedule covers {} requests, instance has {}",
            schedule.slots.len(),
            inst.num_requests()
        ));
    }
    let mut gamma_used = vec![0.0; inst.num_servers()];
    let mut eta_used = vec![0.0; inst.num_servers()];
    for (i, slot) in schedule.slots.iter().enumerate() {
        let Some(a) = slot else { continue };
        if a.request.0 != i {
            return Err(format!("slot {i} holds assignment for request {}", a.request.0));
        }
        let req = &inst.requests[i];
        let cand = &a.candidate;
        // (2f): server/tier must exist and be placed.
        if cand.server.0 >= inst.num_servers() {
            return Err(format!("request {i} assigned to unknown server"));
        }
        // A down server (scenario outage) can serve nothing, under every
        // constraint relaxation; a down covering edge cannot forward.
        if !inst.topology.servers[cand.server.0].up {
            return Err(format!("request {i}: assigned to down server {}", cand.server));
        }
        if cand.offloaded && !inst.topology.servers[req.covering.0].up {
            return Err(format!(
                "request {i}: offloaded through down covering edge {}",
                req.covering
            ));
        }
        if !inst.placement.has(cand.server.0, req.service, cand.tier) {
            return Err(format!("request {i}: model not placed on {}", cand.server));
        }
        // (2b)/(2c).
        if mode.qos && !qos_satisfied(req, cand) {
            return Err(format!(
                "request {i}: QoS violated (a={:.1} A={:.1}, c={:.0} C={:.0})",
                cand.accuracy_pct, req.min_accuracy_pct, cand.completion_ms, req.max_completion_ms
            ));
        }
        // Consistency of the cached candidate numbers with the instance.
        let expect_c = inst.completion_ms(req, cand.server, cand.tier);
        if (expect_c - cand.completion_ms).abs() > 1e-6 {
            return Err(format!(
                "request {i}: stale completion time {} vs {}",
                cand.completion_ms, expect_c
            ));
        }
        gamma_used[cand.server.0] += cand.comp_cost;
        if cand.offloaded {
            eta_used[req.covering.0] += cand.comm_cost;
        }
    }
    for j in 0..inst.num_servers() {
        // Capacities via the instance accessors so per-frame residual γ
        // (DES) binds the check exactly like the steady-state value.
        let (gamma_j, eta_j) = (inst.gamma(j), inst.eta(j));
        if mode.computation && gamma_used[j] > gamma_j + 1e-9 {
            return Err(format!("server {j}: γ exceeded ({} > {})", gamma_used[j], gamma_j));
        }
        if mode.communication && eta_used[j] > eta_j + 1e-9 {
            return Err(format!("server {j}: η exceeded ({} > {})", eta_used[j], eta_j));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::server::ServerId;
    use crate::model::service::TierId;

    fn req() -> Request {
        Request::new(0, 0, 0).with_qos(50.0, 2000.0)
    }

    fn cand(acc: f64, comp: f64) -> Candidate {
        Candidate {
            server: ServerId(1),
            tier: TierId(0),
            accuracy_pct: acc,
            completion_ms: comp,
            comp_cost: 1.0,
            comm_cost: 1.0,
            offloaded: true,
        }
    }

    #[test]
    fn us_formula_matches_paper() {
        // w_a (a - A)/Max_as + w_c (C - c)/Max_cs
        let r = req();
        let c = cand(70.0, 1500.0);
        let us = user_satisfaction(&r, &c, 100.0, 12_000.0);
        let expect = (70.0 - 50.0) / 100.0 + (2000.0 - 1500.0) / 12_000.0;
        assert!((us - expect).abs() < 1e-12);
    }

    #[test]
    fn us_weights_scale_terms() {
        let r = req().with_weights(0.5, 0.0);
        let c = cand(70.0, 1500.0);
        let us = user_satisfaction(&r, &c, 100.0, 12_000.0);
        assert!((us - 0.5 * 0.2).abs() < 1e-12);
    }

    #[test]
    fn qos_boundary_inclusive() {
        let r = req();
        assert!(qos_satisfied(&r, &cand(50.0, 2000.0)));
        assert!(!qos_satisfied(&r, &cand(49.99, 2000.0)));
        assert!(!qos_satisfied(&r, &cand(50.0, 2000.01)));
    }

    #[test]
    fn us_positive_iff_qos_met_with_full_weights() {
        let r = req();
        let good = cand(55.0, 1800.0);
        assert!(qos_satisfied(&r, &good));
        assert!(user_satisfaction(&r, &good, 100.0, 12_000.0) > 0.0);
        let bad = cand(40.0, 5000.0);
        assert!(user_satisfaction(&r, &bad, 100.0, 12_000.0) < 0.0);
    }

    #[test]
    fn objective_averages_over_all_requests() {
        let mut s = Schedule::empty(4);
        s.slots[0] = Some(Assignment { request: RequestId(0), candidate: cand(60.0, 100.0), us: 0.4 });
        s.slots[2] = Some(Assignment { request: RequestId(2), candidate: cand(60.0, 100.0), us: 0.2 });
        assert!((s.objective() - 0.15).abs() < 1e-12);
        assert_eq!(s.served(), 2);
        assert_eq!(s.dropped(), 2);
    }

    #[test]
    fn empty_schedule_objective_zero() {
        assert_eq!(Schedule::empty(0).objective(), 0.0);
    }

    fn two_server_instance(second_up: bool) -> ProblemInstance<'static> {
        use crate::model::server::{Server, ServerClass};
        use crate::model::service::{CatalogParams, Placement, ServiceCatalog};
        use crate::model::Topology;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(1);
        let topology = Topology::explicit(
            vec![
                Server::new(0, ServerClass::EdgeMedium).with_capacities(5.0, 5.0),
                Server::new(1, ServerClass::EdgeLarge)
                    .with_capacities(5.0, 5.0)
                    .with_up(second_up),
            ],
            vec![vec![0.0, 1.0], vec![1.0, 0.0]],
        );
        let catalog = ServiceCatalog::synthetic(
            &CatalogParams { num_services: 1, num_tiers: 1, ..Default::default() },
            &mut rng,
        );
        let placement = Placement::full(&catalog, 2);
        let requests = vec![Request::new(0, 0, 0).with_qos(0.0, 100_000.0)];
        ProblemInstance::new(topology, catalog, placement, requests)
            .with_normalization(100.0, 12_000.0)
    }

    #[test]
    fn tracker_zeroes_down_servers() {
        let inst = two_server_instance(false);
        let t = CapacityTracker::new(&inst, ConstraintMode::STRICT);
        assert_eq!(t.gamma[0], 5.0);
        assert_eq!(t.eta[0], 5.0);
        assert_eq!(t.gamma[1], 0.0, "down server must expose no γ");
        assert_eq!(t.eta[1], 0.0, "down server must expose no η");
    }

    #[test]
    fn tracker_reset_matches_fresh_construction() {
        let inst = two_server_instance(true);
        let fresh = CapacityTracker::new(&inst, ConstraintMode::STRICT);
        let mut pooled = CapacityTracker::default();
        // Dirty the pooled tracker, then reset against the instance.
        pooled.gamma.push(999.0);
        pooled.reset(&inst, ConstraintMode::STRICT);
        assert_eq!(pooled.gamma, fresh.gamma);
        assert_eq!(pooled.eta, fresh.eta);
        // A residual γ slice attached to the instance flows through.
        let inst = two_server_instance(true).with_residual_gamma(vec![1.5, 2.5]);
        pooled.reset(&inst, ConstraintMode::STRICT);
        assert_eq!(pooled.gamma, vec![1.5, 2.5]);
    }

    #[test]
    fn down_covering_edge_blocks_offload_even_when_eta_relaxed() {
        // Server 1 is up (a fine target), but covering server 0 is down:
        // offloading must fail in every mode — Happy-Communication drops
        // the η budget, not the physical link.
        let mut inst = two_server_instance(true);
        inst.topology.to_mut().servers[0].up = false;
        let req = &inst.requests[0];
        let tier = TierId(0);
        let profile = inst.catalog.profile(req.service, tier);
        let cand = Candidate {
            server: ServerId(1),
            tier,
            accuracy_pct: profile.accuracy_pct,
            completion_ms: inst.completion_ms(req, ServerId(1), tier),
            comp_cost: profile.comp_cost,
            comm_cost: profile.comm_cost,
            offloaded: true,
        };
        for mode in [ConstraintMode::STRICT, ConstraintMode::HAPPY_COMMUNICATION] {
            let tracker = CapacityTracker::new(&inst, mode);
            assert!(!tracker.fits(req, &cand), "mode {mode:?} must block the dead link");
        }
        let mut s = Schedule::empty(1);
        s.slots[0] = Some(Assignment { request: RequestId(0), candidate: cand, us: 0.1 });
        let err = validate_schedule(&inst, &s, ConstraintMode::HAPPY_COMMUNICATION).unwrap_err();
        assert!(err.contains("down covering edge"), "{err}");
    }

    #[test]
    fn validate_rejects_down_server_assignment() {
        let inst = two_server_instance(false);
        let req = &inst.requests[0];
        let tier = TierId(0);
        let server = ServerId(1);
        let profile = inst.catalog.profile(req.service, tier);
        let candidate = Candidate {
            server,
            tier,
            accuracy_pct: profile.accuracy_pct,
            completion_ms: inst.completion_ms(req, server, tier),
            comp_cost: profile.comp_cost,
            comm_cost: profile.comm_cost,
            offloaded: true,
        };
        let mut s = Schedule::empty(1);
        s.slots[0] = Some(Assignment { request: RequestId(0), candidate, us: 0.1 });
        let err = validate_schedule(&inst, &s, ConstraintMode::STRICT).unwrap_err();
        assert!(err.contains("down server"), "{err}");
        // The identical assignment is fine once the server is back up.
        let inst_up = two_server_instance(true);
        validate_schedule(&inst_up, &s, ConstraintMode::STRICT).unwrap();
    }
}
