//! Post-hoc schedule explanation: classify every dropped request of a
//! finished [`Schedule`] into the shared [`DropReason`] taxonomy and
//! count the candidates each request had. Scheduler-agnostic — it only
//! looks at the instance and the schedule, never at policy internals —
//! so the DES, the serving leader, and future policies all get
//! explainability for free.
//!
//! Classification is by elimination, using **STRICT** capacity
//! semantics: residual γ/η are recomputed by raw subtraction of the
//! served assignments (never `CapacityTracker`, whose debug assertions
//! reject the legal overdraws of the Happy-* relaxations). For relaxed
//! policies the capacity-vs-policy split is therefore a best-effort
//! STRICT reading of the same frame; the deadline / server-down
//! classes are exact for every policy.

use crate::coordinator::us::{qos_satisfied, Schedule};
use crate::model::instance::Candidate;
use crate::model::ProblemInstance;
use crate::obs::DropReason;

/// Where one request ended up, with enough detail to label a trace.
#[derive(Clone, Copy, Debug)]
pub enum Outcome {
    Served { server: usize, tier: usize, us: f64, offloaded: bool },
    Dropped(DropReason),
}

/// Per-request record of one decision frame.
#[derive(Clone, Copy, Debug)]
pub struct RequestOutcome {
    /// Slot index in the schedule / instance.
    pub request: usize,
    /// Placement-feasible candidates enumerated for this request.
    pub considered: usize,
    /// Of those, candidates passing QoS (2b)/(2c) on a reachable server.
    pub qos_feasible: usize,
    pub outcome: Outcome,
}

/// Aggregate explanation of one frame's schedule.
#[derive(Clone, Debug, Default)]
pub struct DecisionExplain {
    pub outcomes: Vec<RequestOutcome>,
    /// Total candidates enumerated across all requests this frame.
    pub candidates_considered: u64,
    drop_reasons: [u64; DropReason::COUNT],
}

impl DecisionExplain {
    pub fn drops(&self, reason: DropReason) -> u64 {
        self.drop_reasons[reason.index()]
    }

    pub fn total_drops(&self) -> u64 {
        self.drop_reasons.iter().sum()
    }
}

/// Explain a finished schedule against its instance.
///
/// Dropped requests classify by elimination: no live reachable
/// candidate → [`DropReason::ServerDown`]; none QoS-feasible →
/// [`DropReason::DeadlineInfeasible`]; none fits the residual capacity
/// left by the served assignments → [`DropReason::CapacityExhausted`];
/// otherwise the policy itself declined → [`DropReason::Policy`].
pub fn explain_schedule(inst: &ProblemInstance, schedule: &Schedule) -> DecisionExplain {
    debug_assert_eq!(schedule.slots.len(), inst.num_requests());
    // Residual γ/η with every served assignment committed. Raw
    // subtraction, not CapacityTracker: relaxed policies may legally
    // overdraw, and a negative residual simply means nothing else fits.
    // γ reads through the instance accessor so a DES frame's residual
    // slice is honored.
    let mut gamma: Vec<f64> = Vec::with_capacity(inst.num_servers());
    let mut eta: Vec<f64> = Vec::with_capacity(inst.num_servers());
    for (j, s) in inst.topology.servers.iter().enumerate() {
        gamma.push(if s.up { inst.gamma(j) } else { 0.0 });
        eta.push(if s.up { inst.eta(j) } else { 0.0 });
    }
    for (i, slot) in schedule.slots.iter().enumerate() {
        if let Some(a) = slot {
            gamma[a.candidate.server.0] -= a.candidate.comp_cost;
            if a.candidate.offloaded {
                eta[inst.requests[i].covering.0] -= a.candidate.comm_cost;
            }
        }
    }

    let mut out = DecisionExplain::default();
    out.outcomes.reserve(inst.num_requests());
    // One candidate buffer reused across all requests; reachability, QoS
    // feasibility, and capacity fit are counted in a single pass instead
    // of materializing filtered copies.
    let mut cands: Vec<Candidate> = Vec::new();
    for (i, slot) in schedule.slots.iter().enumerate() {
        let req = &inst.requests[i];
        let covering_up = inst.topology.servers[req.covering.0].up;
        inst.candidates_into(i, &mut cands);
        let considered = cands.len();
        let mut n_reachable = 0usize;
        let mut n_qos = 0usize;
        let mut any_fits = false;
        for c in cands.iter() {
            // Offloading rides the covering edge's uplink; with that edge
            // down, remote candidates are physically unreachable.
            if c.offloaded && !covering_up {
                continue;
            }
            n_reachable += 1;
            if !qos_satisfied(req, c) {
                continue;
            }
            n_qos += 1;
            if fits_residual(c, req.covering.0, &gamma, &eta) {
                any_fits = true;
            }
        }
        let outcome = match slot {
            Some(a) => Outcome::Served {
                server: a.candidate.server.0,
                tier: a.candidate.tier.0,
                us: a.us,
                offloaded: a.candidate.offloaded,
            },
            None => {
                let reason = if n_reachable == 0 {
                    DropReason::ServerDown
                } else if n_qos == 0 {
                    DropReason::DeadlineInfeasible
                } else if !any_fits {
                    DropReason::CapacityExhausted
                } else {
                    DropReason::Policy
                };
                out.drop_reasons[reason.index()] += 1;
                Outcome::Dropped(reason)
            }
        };
        out.candidates_considered += considered as u64;
        out.outcomes.push(RequestOutcome {
            request: i,
            considered,
            qos_feasible: n_qos,
            outcome,
        });
    }
    out
}

fn fits_residual(c: &Candidate, covering: usize, gamma: &[f64], eta: &[f64]) -> bool {
    gamma[c.server.0] + 1e-9 >= c.comp_cost
        && (!c.offloaded || eta[covering] + 1e-9 >= c.comm_cost)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::us::Assignment;
    use crate::model::request::RequestId;
    use crate::model::server::{Server, ServerClass};
    use crate::model::service::TierProfile;
    use crate::model::{Placement, Request, ServiceCatalog, Topology};

    /// One service, one tier, fixed costs: comp 1, comm 1, proc 100 ms,
    /// accuracy 90% — so every classification threshold is exact.
    fn catalog1() -> ServiceCatalog {
        ServiceCatalog::from_profiles(vec![vec![TierProfile {
            accuracy_pct: 90.0,
            proc_ms: [100.0; ServerClass::COUNT],
            comp_cost: 1.0,
            comm_cost: 1.0,
            model_bytes: 0,
        }]])
    }

    /// Two edge servers (ids 0, 1), 1 ms apart, full placement.
    fn inst_with(gamma: f64, ups: [bool; 2], requests: Vec<Request>) -> ProblemInstance<'static> {
        let topology = Topology::explicit(
            vec![
                Server::new(0, ServerClass::EdgeMedium)
                    .with_capacities(gamma, 5.0)
                    .with_up(ups[0]),
                Server::new(1, ServerClass::EdgeLarge)
                    .with_capacities(gamma, 5.0)
                    .with_up(ups[1]),
            ],
            vec![vec![0.0, 1.0], vec![1.0, 0.0]],
        );
        let catalog = catalog1();
        let placement = Placement::full(&catalog, 2);
        ProblemInstance::new(topology, catalog, placement, requests)
            .with_normalization(100.0, 12_000.0)
    }

    fn local_assignment(inst: &ProblemInstance, i: usize) -> Assignment {
        let cand = inst
            .candidates(i)
            .into_iter()
            .find(|c| !c.offloaded)
            .expect("local candidate");
        Assignment { request: RequestId(i), candidate: cand, us: 0.5 }
    }

    #[test]
    fn served_requests_report_their_assignment() {
        let inst = inst_with(4.0, [true, true], vec![Request::new(0, 0, 0)]);
        let mut schedule = Schedule::empty(1);
        schedule.slots[0] = Some(local_assignment(&inst, 0));
        let ex = explain_schedule(&inst, &schedule);
        assert_eq!(ex.total_drops(), 0);
        assert_eq!(ex.outcomes.len(), 1);
        // full placement on 2 servers × 1 tier = 2 candidates
        assert_eq!(ex.candidates_considered, 2);
        match ex.outcomes[0].outcome {
            Outcome::Served { server, offloaded, .. } => {
                assert_eq!(server, 0);
                assert!(!offloaded);
            }
            other => panic!("expected Served, got {other:?}"),
        }
    }

    #[test]
    fn impossible_deadline_classifies_as_deadline_infeasible() {
        let req = Request::new(0, 0, 0).with_qos(0.0, 0.0); // proc is 100 ms
        let inst = inst_with(4.0, [true, true], vec![req]);
        let ex = explain_schedule(&inst, &Schedule::empty(1));
        assert_eq!(ex.drops(DropReason::DeadlineInfeasible), 1);
        assert_eq!(ex.outcomes[0].qos_feasible, 0);
        assert_eq!(ex.outcomes[0].considered, 2);
    }

    #[test]
    fn down_covering_edge_classifies_as_server_down() {
        // Covering edge 0 is down: its local replicas are gone from the
        // candidate set, and server 1 is unreachable without the uplink.
        let req = Request::new(0, 0, 0).with_qos(0.0, 100_000.0);
        let inst = inst_with(4.0, [false, true], vec![req]);
        let ex = explain_schedule(&inst, &Schedule::empty(1));
        assert_eq!(ex.drops(DropReason::ServerDown), 1);
    }

    #[test]
    fn spent_capacity_classifies_as_capacity_exhausted() {
        // γ = 1 per server, server 1 down → only the local slot exists;
        // request 0 takes it, request 1 finds residual γ = 0.
        let reqs = vec![
            Request::new(0, 0, 0).with_qos(0.0, 100_000.0),
            Request::new(1, 0, 0).with_qos(0.0, 100_000.0),
        ];
        let inst = inst_with(1.0, [true, false], reqs);
        let mut schedule = Schedule::empty(2);
        schedule.slots[0] = Some(local_assignment(&inst, 0));
        let ex = explain_schedule(&inst, &schedule);
        assert_eq!(ex.drops(DropReason::CapacityExhausted), 1);
        assert_eq!(ex.total_drops(), 1);
    }

    #[test]
    fn unforced_drop_classifies_as_policy() {
        // Plenty of γ left: a feasible candidate fit, the policy passed.
        let req = Request::new(0, 0, 0).with_qos(0.0, 100_000.0);
        let inst = inst_with(4.0, [true, true], vec![req]);
        let ex = explain_schedule(&inst, &Schedule::empty(1));
        assert_eq!(ex.drops(DropReason::Policy), 1);
    }
}
