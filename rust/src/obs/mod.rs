//! Zero-dependency observability substrate: a preallocated ring-buffer
//! event recorder with typed spans / counters / gauges, a shared
//! drop-reason taxonomy for scheduler explainability, and two exporters
//! (Chrome trace-event JSON for Perfetto / chrome://tracing, and
//! Prometheus-style text exposition).
//!
//! Design goals, in priority order:
//!
//! 1. **Free when off.** Recording is disabled by default; every record
//!    call checks a plain `bool` before touching any shared state, so a
//!    disabled recorder costs one predictable branch per call site. The
//!    `obs_overhead` bench enforces a ≤5% DES-throughput budget for the
//!    disabled path.
//! 2. **Allocation-free when on.** The ring is allocated once up front;
//!    event names and labels are `&'static str`. A full ring overwrites
//!    the oldest events rather than growing.
//! 3. **Deterministic exports.** Counters and gauges live in `BTreeMap`s
//!    so exporters emit in sorted order; same run → same bytes.

pub mod prom;
pub mod recorder;
pub mod trace;

pub use prom::prometheus;
pub use recorder::{Event, Key, Phase, Recorder, PID_VIRTUAL, PID_WALL};
pub use trace::chrome_trace;

/// Why a request was not served — the rejection taxonomy shared by the
/// coordinator explainer, the DES, the serving runtime, and both
/// exporters. Labels (`as_str`) are stable: they appear in Prometheus
/// counter labels, trace annotations, and report tables.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DropReason {
    /// No candidate meets the QoS thresholds (2b)/(2c) on any server:
    /// the request was infeasible no matter what the policy did.
    DeadlineInfeasible,
    /// QoS-feasible candidates exist, but the residual γ/η left after
    /// the served assignments cannot host any of them.
    CapacityExhausted,
    /// No live, reachable (server, tier) candidate at all — the target
    /// servers or the covering edge are down (or no replica is placed).
    ServerDown,
    /// The policy declined even though a feasible candidate still fit
    /// (e.g. a greedy ordering spent capacity elsewhere, or Random
    /// picked nothing). Labelled plain "dropped".
    Policy,
    /// Bounced at the admission queue before any decision frame saw it.
    QueueFull,
}

impl DropReason {
    pub const COUNT: usize = 5;

    /// Every reason, in `index()` order.
    pub const ALL: [DropReason; DropReason::COUNT] = [
        DropReason::DeadlineInfeasible,
        DropReason::CapacityExhausted,
        DropReason::ServerDown,
        DropReason::Policy,
        DropReason::QueueFull,
    ];

    /// Stable label used in counters, traces, and report tables.
    pub fn as_str(self) -> &'static str {
        match self {
            DropReason::DeadlineInfeasible => "deadline-infeasible",
            DropReason::CapacityExhausted => "capacity-exhausted",
            DropReason::ServerDown => "server-down",
            DropReason::Policy => "dropped",
            DropReason::QueueFull => "queue-full",
        }
    }

    /// Dense index into per-reason count arrays.
    pub fn index(self) -> usize {
        self as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn drop_reason_indices_are_dense_and_ordered() {
        for (i, r) in DropReason::ALL.iter().enumerate() {
            assert_eq!(r.index(), i);
        }
        assert_eq!(DropReason::ALL.len(), DropReason::COUNT);
    }

    #[test]
    fn drop_reason_labels_are_unique() {
        let labels: Vec<&str> = DropReason::ALL.iter().map(|r| r.as_str()).collect();
        for (i, a) in labels.iter().enumerate() {
            for b in labels.iter().skip(i + 1) {
                assert_ne!(a, b);
            }
        }
    }
}
