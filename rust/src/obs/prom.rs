//! Prometheus-style text-exposition exporter. Renders the recorder's
//! counters and gauges as `# TYPE` blocks with `name{key="value"} v`
//! sample lines — the format `promtool check metrics` and any
//! Prometheus scraper accept. Output is deterministic: metrics emit in
//! sorted (name, label key, label value) order.

use crate::obs::recorder::{Key, Recorder};
use std::fmt::Write as _;

/// Render every counter and gauge in the Prometheus text format.
pub fn prometheus(rec: &Recorder) -> String {
    let mut out = String::new();
    render(&mut out, "counter", &rec.counters());
    render(&mut out, "gauge", &rec.gauges());
    out
}

fn render(out: &mut String, kind: &str, metrics: &[(Key, f64)]) {
    let mut last = "";
    for ((name, label_key, label_val), v) in metrics {
        if *name != last {
            let _ = writeln!(out, "# TYPE {name} {kind}");
            last = name;
        }
        if label_key.is_empty() {
            let _ = writeln!(out, "{name} {v}");
        } else {
            let _ = writeln!(out, "{name}{{{label_key}=\"{label_val}\"}} {v}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::recorder::PID_VIRTUAL;

    #[test]
    fn renders_type_headers_once_per_metric() {
        let r = Recorder::enabled(4);
        r.add_labeled("edgeus_des_dropped_total", "reason", "queue-full", 2.0);
        r.add_labeled("edgeus_des_dropped_total", "reason", "dropped", 1.0);
        r.add("edgeus_des_generated_total", 10.0);
        r.sample("edgeus_des_queue_depth", PID_VIRTUAL, 0, 0.0, 4.0);
        let text = prometheus(&r);
        assert_eq!(
            text.matches("# TYPE edgeus_des_dropped_total counter").count(),
            1
        );
        assert!(text.contains("edgeus_des_dropped_total{reason=\"queue-full\"} 2\n"));
        assert!(text.contains("edgeus_des_dropped_total{reason=\"dropped\"} 1\n"));
        assert!(text.contains("# TYPE edgeus_des_generated_total counter"));
        assert!(text.contains("edgeus_des_generated_total 10\n"));
        assert!(text.contains("# TYPE edgeus_des_queue_depth gauge"));
        assert!(text.contains("edgeus_des_queue_depth 4\n"));
    }

    #[test]
    fn declared_counters_emit_at_zero() {
        let r = Recorder::enabled(4);
        r.declare("edgeus_serve_dropped_total", "reason", "server-down");
        let text = prometheus(&r);
        assert!(text.contains("edgeus_serve_dropped_total{reason=\"server-down\"} 0\n"));
    }

    #[test]
    fn disabled_recorder_renders_empty() {
        assert!(prometheus(&Recorder::disabled()).is_empty());
    }
}
