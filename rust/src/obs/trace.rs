//! Chrome trace-event JSON exporter. The output object loads directly
//! in Perfetto (ui.perfetto.dev) or chrome://tracing: open the file and
//! the virtual-time and wall-clock timelines render as two processes,
//! with one track per server / phase.
//!
//! Format notes (trace-event spec): timestamps and durations are in
//! microseconds; `"X"` = complete span, `"i"` = instant (scope `"t"` =
//! thread), `"C"` = counter, `"M"` = metadata. We stamp simulated
//! milliseconds ×1000 so virtual time reads naturally in the UI.

use crate::obs::recorder::{Event, Phase, Recorder, PID_VIRTUAL, PID_WALL};
use crate::util::json::Json;

/// Export the recorder's ring as a Chrome trace-event JSON object.
/// Deterministic for a given recorder state; round-trips through
/// [`Json::parse`].
pub fn chrome_trace(rec: &Recorder) -> Json {
    let mut events: Vec<Json> = Vec::new();
    for (pid, name) in [(PID_VIRTUAL, "virtual-time"), (PID_WALL, "wall-clock")] {
        events.push(Json::obj(vec![
            ("ph", Json::str("M")),
            ("name", Json::str("process_name")),
            ("pid", Json::num(f64::from(pid))),
            ("tid", Json::num(0.0)),
            ("args", Json::obj(vec![("name", Json::str(name))])),
        ]));
    }
    for e in rec.events() {
        events.push(event_json(&e));
    }
    Json::obj(vec![
        ("displayTimeUnit", Json::str("ms")),
        ("traceEvents", Json::Arr(events)),
        (
            "otherData",
            Json::obj(vec![
                ("total_events", Json::num(rec.total_events() as f64)),
                ("dropped_events", Json::num(rec.dropped_events() as f64)),
            ]),
        ),
    ])
}

fn event_json(e: &Event) -> Json {
    let mut fields: Vec<(&str, Json)> = vec![
        ("name", Json::str(e.name)),
        ("cat", Json::str(e.cat)),
        ("pid", Json::num(f64::from(e.pid))),
        ("tid", Json::num(f64::from(e.track))),
        ("ts", Json::num(e.ts_ms * 1_000.0)),
    ];
    let mut args: Vec<(&str, Json)> = Vec::new();
    if e.id != 0 {
        args.push(("id", Json::num(e.id as f64)));
    }
    if !e.label.is_empty() {
        args.push(("label", Json::str(e.label)));
    }
    match e.phase {
        Phase::Span => {
            fields.push(("ph", Json::str("X")));
            fields.push(("dur", Json::num(e.dur_ms * 1_000.0)));
        }
        Phase::Instant => {
            fields.push(("ph", Json::str("i")));
            fields.push(("s", Json::str("t")));
        }
        Phase::Counter => {
            fields.push(("ph", Json::str("C")));
            args.push((e.name, Json::num(e.value)));
        }
    }
    if !args.is_empty() {
        fields.push(("args", Json::obj(args)));
    }
    Json::obj(fields)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_recorder() -> Recorder {
        let r = Recorder::enabled(16);
        r.span("des", "serve", PID_VIRTUAL, 3, 10.0, 2.5, 42);
        r.instant("des", "drop", PID_VIRTUAL, 1, 11.0, "queue-full", 7);
        r.sample("edgeus_des_queue_depth", PID_VIRTUAL, 0, 12.0, 5.0);
        r
    }

    #[test]
    fn trace_has_metadata_and_all_ring_events() {
        let j = chrome_trace(&demo_recorder());
        let evs = j.get("traceEvents").as_arr().unwrap();
        // 2 process_name metadata records + 3 ring events
        assert_eq!(evs.len(), 5);
        assert_eq!(evs[0].get("ph").as_str().unwrap(), "M");
        assert_eq!(
            evs[0].get("args").get("name").as_str().unwrap(),
            "virtual-time"
        );
        assert_eq!(j.get("displayTimeUnit").as_str().unwrap(), "ms");
    }

    #[test]
    fn span_converts_ms_to_us_and_carries_id() {
        let j = chrome_trace(&demo_recorder());
        let evs = j.get("traceEvents").as_arr().unwrap();
        let span = &evs[2];
        assert_eq!(span.get("ph").as_str().unwrap(), "X");
        assert_eq!(span.get("ts").as_f64().unwrap(), 10_000.0);
        assert_eq!(span.get("dur").as_f64().unwrap(), 2_500.0);
        assert_eq!(span.get("tid").as_f64().unwrap(), 3.0);
        assert_eq!(span.get("args").get("id").as_f64().unwrap(), 42.0);
    }

    #[test]
    fn instant_and_counter_shapes() {
        let j = chrome_trace(&demo_recorder());
        let evs = j.get("traceEvents").as_arr().unwrap();
        let inst = &evs[3];
        assert_eq!(inst.get("ph").as_str().unwrap(), "i");
        assert_eq!(inst.get("s").as_str().unwrap(), "t");
        assert_eq!(inst.get("args").get("label").as_str().unwrap(), "queue-full");
        let ctr = &evs[4];
        assert_eq!(ctr.get("ph").as_str().unwrap(), "C");
        assert_eq!(
            ctr.get("args").get("edgeus_des_queue_depth").as_f64().unwrap(),
            5.0
        );
    }

    #[test]
    fn trace_round_trips_through_json_parse() {
        let j = chrome_trace(&demo_recorder());
        let dump = j.dump();
        let parsed = Json::parse(&dump).expect("trace JSON must parse");
        assert_eq!(parsed.dump(), dump);
        assert_eq!(
            parsed.get("otherData").get("total_events").as_f64().unwrap(),
            3.0
        );
    }
}
