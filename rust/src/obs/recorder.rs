//! Preallocated ring-buffer event recorder.
//!
//! Construct either [`Recorder::enabled`] (one upfront ring allocation)
//! or [`Recorder::disabled`] (no allocation). Every record method checks
//! a plain `bool` before locking, so a disabled recorder adds a single
//! predictable branch per call site and never touches the mutex. Event
//! names and labels are `&'static str`, so recording never allocates;
//! a full ring overwrites the oldest events ([`Recorder::dropped_events`]
//! reports how many were lost).
//!
//! Share across threads as `Arc<Recorder>` — all methods take `&self`.

use std::collections::BTreeMap;
use std::sync::Mutex;

/// Trace "process" id for events stamped in *virtual / simulated* time.
pub const PID_VIRTUAL: u32 = 1;
/// Trace "process" id for events stamped in *wall-clock* time.
pub const PID_WALL: u32 = 2;

/// What an [`Event`] means in the Chrome trace-event model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Complete span (`ph: "X"`): starts at `ts_ms`, lasts `dur_ms`.
    Span,
    /// Instant marker (`ph: "i"`), e.g. an arrival or a world event.
    Instant,
    /// Counter sample (`ph: "C"`): `value` plotted over time.
    Counter,
}

/// One recorded event. `Copy` and allocation-free by construction.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    pub name: &'static str,
    /// Category, e.g. "des", "serve", "scenario".
    pub cat: &'static str,
    pub phase: Phase,
    /// Timeline: [`PID_VIRTUAL`] or [`PID_WALL`].
    pub pid: u32,
    /// Track within the timeline (e.g. a server id); renders as a
    /// trace thread.
    pub track: u32,
    pub ts_ms: f64,
    /// Span duration; 0 for instants and counters.
    pub dur_ms: f64,
    /// Correlation id (request id, decision index); 0 = none.
    pub id: u64,
    /// Counter value ([`Phase::Counter`] only).
    pub value: f64,
    /// Short static annotation (e.g. a drop reason); "" = none.
    pub label: &'static str,
}

/// Metric key: (name, label key, label value); ("", "") = unlabeled.
pub type Key = (&'static str, &'static str, &'static str);

#[derive(Debug, Default)]
struct Inner {
    ring: Vec<Event>,
    head: usize,
    total: u64,
    counters: BTreeMap<Key, f64>,
    gauges: BTreeMap<Key, f64>,
}

/// See module docs.
#[derive(Debug)]
pub struct Recorder {
    on: bool,
    capacity: usize,
    inner: Mutex<Inner>,
}

impl Recorder {
    /// An enabled recorder holding up to `capacity` ring events
    /// (clamped to ≥ 1).
    pub fn enabled(capacity: usize) -> Recorder {
        let capacity = capacity.max(1);
        Recorder {
            on: true,
            capacity,
            inner: Mutex::new(Inner {
                ring: Vec::with_capacity(capacity),
                ..Inner::default()
            }),
        }
    }

    /// A disabled recorder: every record call is a single branch.
    pub fn disabled() -> Recorder {
        Recorder { on: false, capacity: 0, inner: Mutex::new(Inner::default()) }
    }

    #[inline]
    pub fn is_enabled(&self) -> bool {
        self.on
    }

    fn record(&self, ev: Event) {
        let mut g = self.inner.lock().unwrap();
        if g.ring.len() < self.capacity {
            g.ring.push(ev);
        } else {
            let h = g.head;
            g.ring[h] = ev;
        }
        g.head = (g.head + 1) % self.capacity;
        g.total += 1;
    }

    /// Record a complete span.
    #[allow(clippy::too_many_arguments)]
    pub fn span(
        &self,
        cat: &'static str,
        name: &'static str,
        pid: u32,
        track: u32,
        ts_ms: f64,
        dur_ms: f64,
        id: u64,
    ) {
        if !self.on {
            return;
        }
        self.record(Event {
            name,
            cat,
            phase: Phase::Span,
            pid,
            track,
            ts_ms,
            dur_ms: dur_ms.max(0.0),
            id,
            value: 0.0,
            label: "",
        });
    }

    /// Record an instant marker; `label` annotates it (e.g. a drop
    /// reason or a scripted-event kind).
    #[allow(clippy::too_many_arguments)]
    pub fn instant(
        &self,
        cat: &'static str,
        name: &'static str,
        pid: u32,
        track: u32,
        ts_ms: f64,
        label: &'static str,
        id: u64,
    ) {
        if !self.on {
            return;
        }
        self.record(Event {
            name,
            cat,
            phase: Phase::Instant,
            pid,
            track,
            ts_ms,
            dur_ms: 0.0,
            id,
            value: 0.0,
            label,
        });
    }

    /// Sample a gauge: stores the latest value and drops a counter-track
    /// point on the trace timeline so it plots over time.
    pub fn sample(&self, name: &'static str, pid: u32, track: u32, ts_ms: f64, value: f64) {
        if !self.on {
            return;
        }
        self.inner.lock().unwrap().gauges.insert((name, "", ""), value);
        self.record(Event {
            name,
            cat: "gauge",
            phase: Phase::Counter,
            pid,
            track,
            ts_ms,
            dur_ms: 0.0,
            id: 0,
            value,
            label: "",
        });
    }

    /// Add to an unlabeled monotonic counter.
    pub fn add(&self, name: &'static str, delta: f64) {
        self.add_labeled(name, "", "", delta);
    }

    /// Add to a labeled counter (one label key/value pair).
    pub fn add_labeled(
        &self,
        name: &'static str,
        label_key: &'static str,
        label_val: &'static str,
        delta: f64,
    ) {
        if !self.on {
            return;
        }
        let mut g = self.inner.lock().unwrap();
        *g.counters.entry((name, label_key, label_val)).or_insert(0.0) += delta;
    }

    /// Pre-register a counter at zero so exporters always emit it even
    /// if it never fires (drop-reason counters rely on this).
    pub fn declare(&self, name: &'static str, label_key: &'static str, label_val: &'static str) {
        if !self.on {
            return;
        }
        let mut g = self.inner.lock().unwrap();
        g.counters.entry((name, label_key, label_val)).or_insert(0.0);
    }

    // ---- read side -----------------------------------------------------

    /// Ring contents, oldest first.
    pub fn events(&self) -> Vec<Event> {
        let g = self.inner.lock().unwrap();
        if g.ring.len() < self.capacity {
            g.ring.clone()
        } else {
            let mut out = Vec::with_capacity(g.ring.len());
            out.extend_from_slice(&g.ring[g.head..]);
            out.extend_from_slice(&g.ring[..g.head]);
            out
        }
    }

    /// Total events ever recorded, including overwritten ones.
    pub fn total_events(&self) -> u64 {
        self.inner.lock().unwrap().total
    }

    /// Events lost to ring overwrite.
    pub fn dropped_events(&self) -> u64 {
        let g = self.inner.lock().unwrap();
        g.total - g.ring.len() as u64
    }

    /// All counters, sorted by (name, label key, label value).
    pub fn counters(&self) -> Vec<(Key, f64)> {
        self.inner.lock().unwrap().counters.iter().map(|(k, v)| (*k, *v)).collect()
    }

    /// All gauges (latest sampled value per name), sorted by key.
    pub fn gauges(&self) -> Vec<(Key, f64)> {
        self.inner.lock().unwrap().gauges.iter().map(|(k, v)| (*k, *v)).collect()
    }

    /// Current value of one counter (0.0 if never touched). Pass ""
    /// for both label parts to read an unlabeled counter.
    pub fn counter_value(&self, name: &str, label_key: &str, label_val: &str) -> f64 {
        self.inner
            .lock()
            .unwrap() // lint:allow(unwrap) — mutex poisoning is fatal by design
            .counters
            .iter()
            .find(|((n, lk, lv), _)| *n == name && *lk == label_key && *lv == label_val)
            .map(|(_, v)| *v)
            .unwrap_or(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_recorder_records_nothing() {
        let r = Recorder::disabled();
        assert!(!r.is_enabled());
        r.span("t", "s", PID_VIRTUAL, 0, 1.0, 2.0, 1);
        r.instant("t", "i", PID_VIRTUAL, 0, 1.0, "x", 2);
        r.sample("g", PID_VIRTUAL, 0, 1.0, 42.0);
        r.add("c", 1.0);
        r.declare("d", "k", "v");
        assert_eq!(r.total_events(), 0);
        assert!(r.events().is_empty());
        assert!(r.counters().is_empty());
        assert!(r.gauges().is_empty());
        assert_eq!(r.counter_value("c", "", ""), 0.0);
    }

    #[test]
    fn ring_overwrites_oldest_and_reads_in_order() {
        let r = Recorder::enabled(3);
        for i in 0..5u64 {
            r.instant("t", "i", PID_VIRTUAL, 0, i as f64, "", i);
        }
        assert_eq!(r.total_events(), 5);
        assert_eq!(r.dropped_events(), 2);
        let ids: Vec<u64> = r.events().iter().map(|e| e.id).collect();
        assert_eq!(ids, vec![2, 3, 4]);
    }

    #[test]
    fn partial_ring_reads_in_insertion_order() {
        let r = Recorder::enabled(8);
        r.instant("t", "a", PID_VIRTUAL, 0, 0.0, "", 1);
        r.span("t", "b", PID_WALL, 2, 1.0, 0.5, 2);
        assert_eq!(r.dropped_events(), 0);
        let evs = r.events();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].name, "a");
        assert_eq!(evs[1].name, "b");
        assert_eq!(evs[1].phase, Phase::Span);
    }

    #[test]
    fn counters_accumulate_and_sort_by_key() {
        let r = Recorder::enabled(4);
        r.add("z_total", 1.0);
        r.add_labeled("a_total", "reason", "x", 2.0);
        r.add_labeled("a_total", "reason", "x", 3.0);
        r.declare("a_total", "reason", "never");
        let c = r.counters();
        assert_eq!(c.len(), 3);
        assert_eq!(c[0], (("a_total", "reason", "never"), 0.0));
        assert_eq!(c[1], (("a_total", "reason", "x"), 5.0));
        assert_eq!(c[2], (("z_total", "", ""), 1.0));
        assert_eq!(r.counter_value("a_total", "reason", "x"), 5.0);
    }

    #[test]
    fn gauges_keep_latest_value() {
        let r = Recorder::enabled(8);
        r.sample("depth", PID_VIRTUAL, 0, 0.0, 3.0);
        r.sample("depth", PID_VIRTUAL, 0, 1.0, 7.0);
        assert_eq!(r.gauges(), vec![(("depth", "", ""), 7.0)]);
        // each sample also leaves a plottable ring event
        assert_eq!(r.events().len(), 2);
        assert_eq!(r.events()[1].value, 7.0);
    }

    #[test]
    fn negative_span_duration_is_clamped() {
        let r = Recorder::enabled(2);
        r.span("t", "s", PID_WALL, 0, 5.0, -1.0, 0);
        assert_eq!(r.events()[0].dur_ms, 0.0);
    }
}
