//! Monte-Carlo harness for the numerical experiments (§IV: "we run each
//! test for 20000 Monte-Carlo runs and report the average").
//!
//! Each run draws a fresh instance from `ScenarioParams` (new topology
//! jitter, catalog, placement and request population), schedules it with
//! every policy under test, and accumulates the per-policy metrics.
//! Runs are distributed across OS threads; every run's RNG is seeded from
//! (base_seed, run_index) so results are independent of thread count.

use crate::coordinator::{all_schedulers, Scheduler};
use crate::model::ProblemInstance;
use crate::util::rng::Rng;
use crate::util::stats::Accumulator;
use crate::workload::{build_instance, ScenarioParams};

/// Per-policy aggregated metrics over all runs.
#[derive(Clone, Debug, Default)]
pub struct PolicyStats {
    pub name: String,
    pub satisfied_pct: Accumulator,
    pub served_pct: Accumulator,
    pub objective: Accumulator,
    /// Decision mix (percent): local / cloud / peer / dropped.
    pub mix_local: Accumulator,
    pub mix_cloud: Accumulator,
    pub mix_peer: Accumulator,
    pub mix_dropped: Accumulator,
}

impl PolicyStats {
    fn record(&mut self, inst: &ProblemInstance, schedule: &crate::coordinator::Schedule) {
        let n = inst.num_requests().max(1) as f64;
        self.satisfied_pct.push(schedule.satisfied_pct(inst));
        self.served_pct.push(100.0 * schedule.served() as f64 / n);
        self.objective.push(schedule.objective());
        let mix = schedule.decision_mix_pct(inst);
        self.mix_local.push(mix[0]);
        self.mix_cloud.push(mix[1]);
        self.mix_peer.push(mix[2]);
        self.mix_dropped.push(mix[3]);
    }

    fn merge(&mut self, other: &PolicyStats) {
        self.satisfied_pct.merge(&other.satisfied_pct);
        self.served_pct.merge(&other.served_pct);
        self.objective.merge(&other.objective);
        self.mix_local.merge(&other.mix_local);
        self.mix_cloud.merge(&other.mix_cloud);
        self.mix_peer.merge(&other.mix_peer);
        self.mix_dropped.merge(&other.mix_dropped);
    }
}

/// Configuration of one Monte-Carlo experiment.
#[derive(Clone, Debug)]
pub struct MonteCarlo {
    pub scenario: ScenarioParams,
    pub runs: usize,
    pub base_seed: u64,
    pub threads: usize,
}

impl Default for MonteCarlo {
    fn default() -> Self {
        MonteCarlo {
            scenario: ScenarioParams::default(),
            runs: 200,
            base_seed: 7,
            threads: default_threads(),
        }
    }
}

pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

impl MonteCarlo {
    /// Run with the standard six policies.
    pub fn run(&self) -> Vec<PolicyStats> {
        self.run_with(&all_schedulers)
    }

    /// Run with a custom policy set (factory is invoked per worker thread
    /// — trait objects are not Sync-shareable across scheduling calls
    /// with interior state).
    pub fn run_with(
        &self,
        factory: &(dyn Fn() -> Vec<Box<dyn Scheduler + Send + Sync>> + Sync),
    ) -> Vec<PolicyStats> {
        let threads = self.threads.max(1).min(self.runs.max(1));
        let runs = self.runs;
        let chunk = runs.div_ceil(threads);
        let mut partials: Vec<Vec<PolicyStats>> = Vec::new();
        std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for t in 0..threads {
                let lo = t * chunk;
                let hi = ((t + 1) * chunk).min(runs);
                if lo >= hi {
                    continue;
                }
                let scenario = self.scenario.clone();
                let base_seed = self.base_seed;
                handles.push(scope.spawn(move || {
                    let schedulers = factory();
                    let mut stats: Vec<PolicyStats> = schedulers
                        .iter()
                        .map(|s| PolicyStats { name: s.name().to_string(), ..Default::default() })
                        .collect();
                    for run in lo..hi {
                        // Per-run deterministic seed, independent of threads.
                        let mut rng =
                            Rng::new(base_seed ^ (run as u64).wrapping_mul(0xA24BAED4963EE407));
                        let inst = build_instance(&scenario, &mut rng);
                        for (si, sched) in schedulers.iter().enumerate() {
                            let mut srng = rng.fork(si as u64);
                            let schedule = sched.schedule(&inst, &mut srng);
                            stats[si].record(&inst, &schedule);
                        }
                    }
                    stats
                }));
            }
            for h in handles {
                partials.push(h.join().expect("monte-carlo worker panicked")); // lint:allow(unwrap) — propagate worker panics
            }
        });
        let mut merged: Vec<PolicyStats> = Vec::new();
        for part in partials {
            if merged.is_empty() {
                merged = part;
            } else {
                for (m, p) in merged.iter_mut().zip(part.iter()) {
                    debug_assert_eq!(m.name, p.name);
                    m.merge(p);
                }
            }
        }
        merged
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::service::CatalogParams;
    use crate::model::topology::TopologyParams;
    use crate::workload::WorkloadParams;

    fn quick() -> MonteCarlo {
        MonteCarlo {
            scenario: ScenarioParams {
                topology: TopologyParams { num_edge: 4, num_cloud: 1, ..Default::default() },
                catalog: CatalogParams { num_services: 10, num_tiers: 4, ..Default::default() },
                workload: WorkloadParams { num_requests: 30, ..Default::default() },
            },
            runs: 16,
            base_seed: 3,
            threads: 4,
        }
    }

    #[test]
    fn aggregates_all_policies() {
        let stats = quick().run();
        assert_eq!(stats.len(), 6);
        for s in &stats {
            assert_eq!(s.satisfied_pct.count(), 16);
            assert!(s.satisfied_pct.mean() >= 0.0 && s.satisfied_pct.mean() <= 100.0);
            let mix_sum = s.mix_local.mean() + s.mix_cloud.mean() + s.mix_peer.mean()
                + s.mix_dropped.mean();
            assert!((mix_sum - 100.0).abs() < 1e-6, "{}: mix sums to {mix_sum}", s.name);
        }
    }

    #[test]
    fn thread_count_does_not_change_results() {
        let mut a = quick();
        a.threads = 1;
        let mut b = quick();
        b.threads = 8;
        let ra = a.run();
        let rb = b.run();
        for (x, y) in ra.iter().zip(rb.iter()) {
            assert_eq!(x.name, y.name);
            assert!((x.satisfied_pct.mean() - y.satisfied_pct.mean()).abs() < 1e-9);
            assert!((x.objective.mean() - y.objective.mean()).abs() < 1e-9);
        }
    }

    #[test]
    fn gus_beats_naive_baselines_on_average() {
        let mut mc = quick();
        mc.runs = 24;
        let stats = mc.run();
        let by_name = |n: &str| stats.iter().find(|s| s.name == n).unwrap();
        let gus = by_name("gus").satisfied_pct.mean();
        assert!(gus >= by_name("random").satisfied_pct.mean());
        assert!(gus >= by_name("offload-all").satisfied_pct.mean());
        assert!(gus >= by_name("local-all").satisfied_pct.mean());
    }
}
