//! Admission-control queueing (paper §II "Completion time"): requests
//! arriving at an edge server wait in a bounded admission queue until the
//! end of the decision time frame (or until the queue fills), accruing
//! queuing delay T^q. The serving path uses this directly; the numerical
//! experiments draw T^q from its marginal distribution instead.

/// One queued request with its arrival timestamp.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Queued<T> {
    pub item: T,
    pub arrival_ms: f64,
}

/// Bounded FIFO admission queue for one edge server.
#[derive(Clone, Debug)]
pub struct AdmissionQueue<T> {
    items: std::collections::VecDeque<Queued<T>>,
    capacity: usize,
    /// Requests rejected because the queue was full.
    pub rejected: u64,
}

impl<T> AdmissionQueue<T> {
    /// Paper testbed: queue length 4.
    pub fn new(capacity: usize) -> AdmissionQueue<T> {
        assert!(capacity > 0);
        AdmissionQueue {
            items: std::collections::VecDeque::with_capacity(capacity),
            capacity,
            rejected: 0,
        }
    }

    /// Try to admit; returns false (and counts a rejection) when full.
    pub fn push(&mut self, item: T, now_ms: f64) -> bool {
        if self.items.len() >= self.capacity {
            self.rejected += 1;
            return false;
        }
        self.items.push_back(Queued { item, arrival_ms: now_ms });
        true
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn is_full(&self) -> bool {
        self.items.len() >= self.capacity
    }

    /// Drain everything for a decision frame, returning (item, T^q) pairs
    /// where T^q = now - arrival.
    pub fn drain(&mut self, now_ms: f64) -> Vec<(T, f64)> {
        let mut out = Vec::with_capacity(self.items.len());
        self.drain_with(now_ms, |item, tq| out.push((item, tq)));
        out
    }

    /// Allocation-free drain: invoke `f(item, T^q)` for each queued entry
    /// in FIFO order. The DES hot path collects into a pooled frame
    /// buffer through this instead of allocating a Vec per queue per
    /// frame.
    pub fn drain_with(&mut self, now_ms: f64, mut f: impl FnMut(T, f64)) {
        for q in self.items.drain(..) {
            f(q.item, (now_ms - q.arrival_ms).max(0.0));
        }
    }
}

/// The decision clock: a frame ends every `frame_ms` (paper testbed:
/// 3000 ms) or when any queue fills, whichever comes first.
#[derive(Clone, Copy, Debug)]
pub struct FrameClock {
    pub frame_ms: f64,
    next_deadline_ms: f64,
}

impl FrameClock {
    pub fn new(frame_ms: f64) -> FrameClock {
        assert!(frame_ms > 0.0);
        FrameClock { frame_ms, next_deadline_ms: frame_ms }
    }

    /// Should a decision run at `now`, given whether some queue is full?
    pub fn should_fire(&self, now_ms: f64, any_queue_full: bool) -> bool {
        any_queue_full || now_ms >= self.next_deadline_ms
    }

    /// Mark a decision as run at `now`; schedules the next deadline.
    pub fn fired(&mut self, now_ms: f64) {
        self.next_deadline_ms = now_ms + self.frame_ms;
    }

    pub fn next_deadline_ms(&self) -> f64 {
        self.next_deadline_ms
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order_and_tq() {
        let mut q = AdmissionQueue::new(4);
        assert!(q.push("a", 0.0));
        assert!(q.push("b", 100.0));
        let drained = q.drain(250.0);
        assert_eq!(drained.len(), 2);
        assert_eq!(drained[0], ("a", 250.0));
        assert_eq!(drained[1], ("b", 150.0));
        assert!(q.is_empty());
    }

    #[test]
    fn rejects_when_full() {
        let mut q = AdmissionQueue::new(2);
        assert!(q.push(1, 0.0));
        assert!(q.push(2, 0.0));
        assert!(q.is_full());
        assert!(!q.push(3, 0.0));
        assert_eq!(q.rejected, 1);
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn drain_on_empty_is_empty() {
        let mut q: AdmissionQueue<u8> = AdmissionQueue::new(1);
        assert!(q.drain(10.0).is_empty());
    }

    #[test]
    fn tq_never_negative() {
        let mut q = AdmissionQueue::new(2);
        q.push(1, 100.0);
        let drained = q.drain(50.0); // clock skew guard
        assert_eq!(drained[0].1, 0.0);
    }

    #[test]
    fn frame_clock_fires_on_deadline_or_full() {
        let mut c = FrameClock::new(3000.0);
        assert!(!c.should_fire(1000.0, false));
        assert!(c.should_fire(1000.0, true));
        assert!(c.should_fire(3000.0, false));
        c.fired(3000.0);
        assert_eq!(c.next_deadline_ms(), 6000.0);
        assert!(!c.should_fire(4000.0, false));
    }
}
