//! Discrete-event simulator: the paper's §II time-slotted scenario with
//! full temporal dynamics — Poisson arrivals accumulate in bounded
//! admission queues, a decision runs at the end of every time frame (or
//! when a queue fills), served requests occupy their server's γ capacity
//! until their completion event fires, and offloads consume the covering
//! edge's per-frame η budget.
//!
//! This complements the two other evaluation paths:
//! * `sim::montecarlo` — the paper's one-decision-round numerical study;
//! * `serving` — the live scaled-real-time runtime with real inference.
//!
//! The DES runs in pure virtual time (fast, exactly reproducible) and
//! exposes dynamics the one-shot study cannot: queue-length evolution,
//! capacity recovery as work drains, and satisfaction vs offered load
//! over a sustained horizon.
//!
//! With a [`crate::scenario::Script`] configured, a
//! [`crate::scenario::ScenarioEngine`] additionally replays typed world
//! events (outages, load bursts, bandwidth drift, user mobility,
//! placement changes) at decision-frame boundaries, and the report grows
//! a per-frame time series ([`FrameSample`]) of satisfaction, queue depth
//! and capacity utilization.

use crate::coordinator::explain::{explain_schedule, Outcome};
use crate::coordinator::{SchedScratch, Schedule, Scheduler};
use crate::model::request::Request;
use crate::model::service::ServiceId;
use crate::model::{Placement, ProblemInstance, ServiceCatalog, Topology};
use crate::obs::{DropReason, Recorder, PID_VIRTUAL, PID_WALL};
use crate::sim::queueing::AdmissionQueue;
use crate::util::rng::Rng;
use crate::util::stats::{Accumulator, Histogram};
use crate::workload::ScenarioParams;
#[cfg(test)]
use crate::workload::WorkloadParams;
use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::time::Instant;

/// Configuration of one DES run.
#[derive(Clone, Debug)]
pub struct DesConfig {
    pub scenario: ScenarioParams,
    /// Virtual horizon over which arrivals occur (ms).
    pub horizon_ms: f64,
    /// Decision frame (paper testbed: 3000 ms).
    pub frame_ms: f64,
    /// Mean offered load (requests per second, Poisson).
    pub arrival_rate_per_s: f64,
    /// Admission queue capacity per edge (paper: 4).
    pub queue_capacity: usize,
    /// Optional scenario script: typed world events (outages, bursts,
    /// bandwidth drift, mobility, placement changes) replayed by a
    /// [`crate::scenario::ScenarioEngine`] at decision-frame boundaries.
    pub script: Option<crate::scenario::Script>,
    pub seed: u64,
}

impl Default for DesConfig {
    fn default() -> Self {
        DesConfig {
            scenario: ScenarioParams::default(),
            horizon_ms: 60_000.0,
            frame_ms: 3_000.0,
            arrival_rate_per_s: 2.0,
            queue_capacity: 4,
            script: None,
            seed: 7,
        }
    }
}

/// One decision-boundary snapshot in [`DesReport::frames`]: cumulative
/// counters as of the decision, plus instantaneous gauges. The scenario
/// sweep resamples these into satisfaction-vs-time series.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FrameSample {
    /// Virtual time of the decision (ms).
    pub t_ms: f64,
    /// Cumulative counters at this boundary.
    pub generated: u64,
    pub served: u64,
    pub satisfied: u64,
    pub dropped: u64,
    pub rejected: u64,
    pub local: u64,
    pub cloud: u64,
    pub peer: u64,
    /// Requests queued across all edges when the decision fired.
    pub queue_depth: u64,
    /// γ in service / total live γ, sampled after the decision committed
    /// (can transiently exceed 1.0 right after an outage shrinks live γ).
    pub capacity_utilization: f64,
    /// Scenario events applied at this boundary.
    pub events_applied: u64,
}

/// Per-frame decision explanation, populated only when the DES runs
/// with an **enabled** [`Recorder`] — so sweeps can answer "why did
/// satisfaction dip at frame k" without replaying. One entry per
/// decision (including queue-full-triggered ones).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FrameExplain {
    /// Virtual time of the decision (ms).
    pub t_ms: f64,
    /// 1-based decision index (matches `DesReport::decisions`).
    pub decision: u64,
    /// Requests drained into this frame's instance.
    pub requests: u64,
    pub served: u64,
    /// Candidates the scheduler had to choose from, summed over requests.
    pub candidates_considered: u64,
    pub drop_deadline_infeasible: u64,
    pub drop_capacity_exhausted: u64,
    pub drop_server_down: u64,
    pub drop_policy: u64,
    /// Wall-clock time the policy spent scheduling this frame (µs).
    pub schedule_wall_us: f64,
    /// Event-calendar depth after the decision committed.
    pub calendar_depth: u64,
    /// Scenario events applied at this boundary.
    pub events_applied: u64,
}

impl FrameExplain {
    pub fn total_drops(&self) -> u64 {
        self.drop_deadline_infeasible
            + self.drop_capacity_exhausted
            + self.drop_server_down
            + self.drop_policy
    }
}

/// Aggregate outcome of one DES run.
#[derive(Clone, Debug, Default)]
pub struct DesReport {
    pub generated: u64,
    pub served: u64,
    pub satisfied: u64,
    pub dropped: u64,
    pub rejected_at_queue: u64,
    pub local: u64,
    pub cloud: u64,
    pub peer: u64,
    pub decisions: u64,
    /// End-to-end completion time of served requests (ms).
    pub completion: Accumulator,
    /// Queue delay T^q actually experienced (ms).
    pub queue_delay: Accumulator,
    /// Mean queue length sampled at each decision.
    pub queue_len: Accumulator,
    /// Latency distribution for percentile reporting.
    pub latency_hist: Histogram,
    /// Per-decision time series (one entry per decision boundary,
    /// including queue-full-triggered ones).
    pub frames: Vec<FrameSample>,
    /// Per-frame decision explanations; empty unless the run had an
    /// enabled [`Recorder`] (keeps default reports byte-identical).
    pub explain: Vec<FrameExplain>,
    /// Rank-cache accounting from the pooled scheduler scratch: requests
    /// whose class ranking was served warm. Zero for policies that keep
    /// no cache and for `run_reference` (which schedules with cold
    /// scratch every frame). Deliberately *not* serialized in
    /// [`DesReport::to_json`]: the dump must stay byte-identical between
    /// cached, uncached, and reference runs.
    pub cache_hits: u64,
    /// Requests whose class ranking had to be (re)built; see `cache_hits`.
    pub cache_misses: u64,
    /// Class rebuilds performed (≤ `cache_misses`).
    pub cache_rebuilds: u64,
}

impl DesReport {
    /// Warm fraction of rank-cache lookups (0.0 when no cache ran).
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }

    pub fn satisfied_pct(&self) -> f64 {
        if self.generated == 0 {
            0.0
        } else {
            100.0 * self.satisfied as f64 / self.generated as f64
        }
    }

    pub fn mix_pct(&self) -> [f64; 4] {
        let n = self.generated.max(1) as f64;
        [
            100.0 * self.local as f64 / n,
            100.0 * self.cloud as f64 / n,
            100.0 * self.peer as f64 / n,
            100.0 * (self.dropped + self.rejected_at_queue) as f64 / n,
        ]
    }

    /// Serialize the full report (counters + per-frame series) as JSON.
    /// Same seed + same config ⇒ byte-identical output — the determinism
    /// tests compare these dumps directly. (With an enabled recorder an
    /// `explain` block is added, whose `schedule_wall_us` is wall-clock;
    /// byte-stability is only guaranteed for recorder-off runs.)
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        // NaN is not representable in JSON; empty accumulators report 0.
        let num = |x: f64| Json::num(if x.is_finite() { x } else { 0.0 });
        let count = |x: u64| Json::num(x as f64);
        let mut fields = vec![
            ("generated", count(self.generated)),
            ("served", count(self.served)),
            ("satisfied", count(self.satisfied)),
            ("dropped", count(self.dropped)),
            ("rejected_at_queue", count(self.rejected_at_queue)),
            ("local", count(self.local)),
            ("cloud", count(self.cloud)),
            ("peer", count(self.peer)),
            ("decisions", count(self.decisions)),
            ("satisfied_pct", num(self.satisfied_pct())),
            ("completion_mean_ms", num(self.completion.mean())),
            ("queue_delay_mean_ms", num(self.queue_delay.mean())),
            ("queue_len_mean", num(self.queue_len.mean())),
            (
                "frames",
                Json::arr(self.frames.iter().map(|f| {
                    Json::obj(vec![
                        ("t_ms", num(f.t_ms)),
                        ("generated", count(f.generated)),
                        ("served", count(f.served)),
                        ("satisfied", count(f.satisfied)),
                        ("dropped", count(f.dropped)),
                        ("rejected", count(f.rejected)),
                        ("local", count(f.local)),
                        ("cloud", count(f.cloud)),
                        ("peer", count(f.peer)),
                        ("queue_depth", count(f.queue_depth)),
                        ("capacity_utilization", num(f.capacity_utilization)),
                        ("events_applied", count(f.events_applied)),
                    ])
                })),
            ),
        ];
        if !self.explain.is_empty() {
            fields.push((
                "explain",
                Json::arr(self.explain.iter().map(|e| {
                    Json::obj(vec![
                        ("t_ms", num(e.t_ms)),
                        ("decision", count(e.decision)),
                        ("requests", count(e.requests)),
                        ("served", count(e.served)),
                        ("candidates_considered", count(e.candidates_considered)),
                        ("drop_deadline_infeasible", count(e.drop_deadline_infeasible)),
                        ("drop_capacity_exhausted", count(e.drop_capacity_exhausted)),
                        ("drop_server_down", count(e.drop_server_down)),
                        ("drop_policy", count(e.drop_policy)),
                        ("schedule_wall_us", num(e.schedule_wall_us)),
                        ("calendar_depth", count(e.calendar_depth)),
                        ("events_applied", count(e.events_applied)),
                    ])
                })),
            ));
        }
        Json::obj(fields)
    }

    /// Verify the run's conservation invariants: every generated request
    /// is accounted for exactly once, the decision-kind split sums to
    /// served, and the per-frame cumulative series is monotone and
    /// self-consistent at every decision boundary.
    pub fn check_conservation(&self) -> Result<(), String> {
        if self.generated != self.served + self.dropped + self.rejected_at_queue {
            return Err(format!(
                "conservation: generated {} != served {} + dropped {} + rejected {}",
                self.generated, self.served, self.dropped, self.rejected_at_queue
            ));
        }
        if self.served != self.local + self.cloud + self.peer {
            return Err(format!(
                "kind split: served {} != local {} + cloud {} + peer {}",
                self.served, self.local, self.cloud, self.peer
            ));
        }
        if self.satisfied > self.served {
            return Err(format!("satisfied {} > served {}", self.satisfied, self.served));
        }
        let mut prev = FrameSample::default();
        for (k, f) in self.frames.iter().enumerate() {
            if f.t_ms < prev.t_ms {
                return Err(format!("frame {k}: time went backwards"));
            }
            let monotone = f.generated >= prev.generated
                && f.served >= prev.served
                && f.satisfied >= prev.satisfied
                && f.dropped >= prev.dropped
                && f.rejected >= prev.rejected
                && f.local >= prev.local
                && f.cloud >= prev.cloud
                && f.peer >= prev.peer;
            if !monotone {
                return Err(format!("frame {k}: cumulative counter decreased"));
            }
            if f.served != f.local + f.cloud + f.peer {
                return Err(format!("frame {k}: kind split does not sum to served"));
            }
            if f.satisfied > f.served {
                return Err(format!("frame {k}: satisfied exceeds served"));
            }
            // Requests still queued or in flight keep generated ahead of
            // the settled counters at any boundary.
            if f.generated < f.served + f.dropped + f.rejected {
                return Err(format!("frame {k}: settled more requests than generated"));
            }
            prev = f.clone();
        }
        if let Some(last) = self.frames.last() {
            if last.generated != self.generated {
                return Err("final frame missed arrivals".to_string());
            }
        }
        Ok(())
    }

    /// Render the per-frame decision explanations as a markdown table
    /// (empty string when the run had no enabled recorder).
    pub fn explain_markdown(&self) -> String {
        if self.explain.is_empty() {
            return String::new();
        }
        let mut out = String::from(
            "| frame | t (ms) | reqs | served | cands | deadline | capacity | down | policy | sched (µs) | cal depth | events |\n\
             |---|---|---|---|---|---|---|---|---|---|---|---|\n",
        );
        for e in &self.explain {
            out.push_str(&format!(
                "| {} | {:.0} | {} | {} | {} | {} | {} | {} | {} | {:.1} | {} | {} |\n",
                e.decision,
                e.t_ms,
                e.requests,
                e.served,
                e.candidates_considered,
                e.drop_deadline_infeasible,
                e.drop_capacity_exhausted,
                e.drop_server_down,
                e.drop_policy,
                e.schedule_wall_us,
                e.calendar_depth,
                e.events_applied,
            ));
        }
        out
    }
}

/// A request waiting for a decision.
#[derive(Clone, Debug)]
struct Pending {
    /// 1-based arrival index; correlates trace spans with instants.
    id: u64,
    service: ServiceId,
    a_min: f64,
    c_max: f64,
    payload: u64,
    arrival_ms: f64,
}

#[derive(Clone, Debug, PartialEq)]
enum Event {
    Arrival,
    Decision,
    /// (server, comp_cost, accuracy, a_min, c_max, arrival_ms, kind)
    Completion {
        server: usize,
        comp_cost: f64,
        accuracy: f64,
        a_min: f64,
        c_max: f64,
        arrival_ms: f64,
        kind: u8, // 0 local, 1 cloud, 2 peer
        id: u64,
    },
}

/// Calendar entry; `seq` breaks ties deterministically.
#[derive(Clone, Debug, PartialEq)]
struct Entry {
    at_ms: f64,
    seq: u64,
    event: Event,
}

impl Eq for Entry {}

impl Ord for Entry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.at_ms
            .total_cmp(&other.at_ms)
            .then(self.seq.cmp(&other.seq))
    }
}

impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// Pooled per-frame working memory, owned by one run and reused across
/// every decision frame: once buffers reach their steady-state size the
/// decision hot path stops allocating entirely.
struct FrameScratch {
    /// (edge position, pending request, T^q) drained this frame.
    drained: Vec<(usize, Pending, f64)>,
    /// Request buffer lent to the frame instance, recovered after.
    requests: Vec<Request>,
    /// Residual-γ slice lent to the frame instance, recovered after.
    residual_gamma: Vec<f64>,
    /// Scheduler working memory (candidate/ranking buffers, tracker).
    sched: SchedScratch,
    /// Reused schedule output.
    schedule: Schedule,
}

/// The simulator.
pub struct Des<'a> {
    cfg: DesConfig,
    scheduler: &'a (dyn Scheduler + Send + Sync),
    recorder: Option<&'a Recorder>,
}

impl<'a> Des<'a> {
    pub fn new(cfg: DesConfig, scheduler: &'a (dyn Scheduler + Send + Sync)) -> Des<'a> {
        Des { cfg, scheduler, recorder: None }
    }

    /// Attach an observability recorder (borrowed — a run never clones
    /// it). A disabled recorder keeps the run (and its report bytes)
    /// identical to a recorder-less run; an enabled one additionally
    /// populates [`DesReport::explain`].
    pub fn with_recorder(mut self, recorder: &'a Recorder) -> Des<'a> {
        self.recorder = Some(recorder);
        self
    }

    /// Run the simulation on the pooled, allocation-free hot path.
    pub fn run(&self) -> DesReport {
        self.run_impl(false)
    }

    /// Run with the pre-pooling decide path: deep-clone the world each
    /// frame and mutate the clone's γ in place. Kept as the golden
    /// oracle — `run()` must match it byte-for-byte on the same seed
    /// (tests/des_golden.rs) — and as the bench baseline for the
    /// before/after throughput numbers in BENCH_des.json.
    pub fn run_reference(&self) -> DesReport {
        self.run_impl(true)
    }

    fn run_impl(&self, reference: bool) -> DesReport {
        // `obs` is Some only for an *enabled* recorder: the hot loop
        // pays one `if let` test per site when observability is off.
        let obs = self.recorder.filter(|r| r.is_enabled());
        let wall_t0 = Instant::now();
        if let Some(r) = obs {
            for reason in DropReason::ALL {
                r.declare("edgeus_des_dropped_total", "reason", reason.as_str());
            }
        }
        let mut rng = Rng::new(self.cfg.seed);
        let mut topology = Topology::paper_default(&self.cfg.scenario.topology, &mut rng);
        let catalog = ServiceCatalog::synthetic(&self.cfg.scenario.catalog, &mut rng);
        let classes: Vec<_> = topology.servers.iter().map(|s| s.class).collect();
        let mut placement = Placement::random(&catalog, &classes, &mut rng);
        let edges = topology.edge_ids();
        let wl = &self.cfg.scenario.workload;
        // Scenario engine (if a script is configured): replays world
        // events at decision boundaries, modulates arrivals in between.
        let mut engine = self.cfg.script.clone().map(|script| {
            crate::scenario::ScenarioEngine::new(
                script,
                &topology,
                catalog.num_services,
                catalog.num_tiers,
            )
        });

        let mut report = DesReport {
            latency_hist: Histogram::exponential(10.0, 2.0, 14),
            ..Default::default()
        };
        let mut queues: Vec<AdmissionQueue<Pending>> =
            edges.iter().map(|_| AdmissionQueue::new(self.cfg.queue_capacity)).collect();
        // γ units currently occupied per server.
        let mut busy = vec![0.0f64; topology.len()];

        // The calendar holds one pending arrival, a handful of decisions,
        // and the in-flight completions — which are bounded by total γ
        // (each served request occupies ≥ its comp_cost γ units). Size it
        // once so steady state never regrows the heap.
        let cal_capacity =
            16 + topology.servers.iter().map(|s| s.gamma.max(0.0).ceil() as usize).sum::<usize>();
        let mut calendar: BinaryHeap<Reverse<Entry>> = BinaryHeap::with_capacity(cal_capacity);
        let mut seq = 0u64;
        let mut scratch = FrameScratch {
            drained: Vec::with_capacity(edges.len() * self.cfg.queue_capacity),
            requests: Vec::with_capacity(edges.len() * self.cfg.queue_capacity),
            residual_gamma: Vec::with_capacity(topology.len()),
            sched: SchedScratch::default(),
            schedule: Schedule::empty(0),
        };
        let mut push = |cal: &mut BinaryHeap<Reverse<Entry>>, seq: &mut u64, at: f64, ev: Event| {
            *seq += 1;
            cal.push(Reverse(Entry { at_ms: at, seq: *seq, event: ev }));
        };
        let gap = 1000.0 / self.cfg.arrival_rate_per_s.max(1e-9);
        push(&mut calendar, &mut seq, rng.uniform(0.0, gap), Event::Arrival);
        push(&mut calendar, &mut seq, self.cfg.frame_ms, Event::Decision);

        // lint:no-alloc:begin — DES event loop: every buffer is warm by
        // here; steady state must not allocate (PR 3's ≥3× speedup gate
        // assumes it, `tools/lint.rs` enforces it in CI).
        while let Some(Reverse(entry)) = calendar.pop() {
            let now = entry.at_ms;
            match entry.event {
                Event::Arrival => {
                    if now <= self.cfg.horizon_ms {
                        report.generated += 1;
                        // Covering edge: uniform without a scenario (the
                        // seed behaviour, draw-for-draw); weighted over
                        // live edges under mobility/outage scripts.
                        let edge_pos = match &engine {
                            Some(e) => e.pick_edge(&topology, &mut rng),
                            None => rng.index(edges.len()),
                        };
                        let pending = Pending {
                            id: report.generated,
                            service: ServiceId(rng.index(catalog.num_services)),
                            a_min: rng.normal_clamped(
                                wl.accuracy_mean_pct,
                                wl.accuracy_std_pct,
                                0.0,
                                100.0,
                            ),
                            c_max: rng.normal_clamped(
                                wl.deadline_mean_ms,
                                wl.deadline_std_ms,
                                0.0,
                                wl.max_completion_ms,
                            ),
                            payload: rng.u64_range(wl.payload_lo_bytes, wl.payload_hi_bytes),
                            arrival_ms: now,
                        };
                        let queue = &mut queues[edge_pos];
                        let was_admitted = queue.push(pending, now);
                        if let Some(r) = obs {
                            let track = edges[edge_pos].0 as u32;
                            r.add("edgeus_des_generated_total", 1.0);
                            r.instant("des", "arrival", PID_VIRTUAL, track, now, "", report.generated);
                            if !was_admitted {
                                let reason = DropReason::QueueFull.as_str();
                                r.add_labeled("edgeus_des_dropped_total", "reason", reason, 1.0);
                                r.instant("des", "drop", PID_VIRTUAL, track, now, reason, report.generated);
                            }
                        }
                        if !was_admitted {
                            report.rejected_at_queue += 1;
                        } else if queue.is_full() {
                            // Paper: the decision also runs when a queue
                            // fills before the frame deadline.
                            push(&mut calendar, &mut seq, now, Event::Decision);
                        }
                        // Next arrival (exponential gap; `LoadBurst`
                        // windows shrink the mean gap).
                        let mult = engine
                            .as_ref()
                            .map(|e| e.arrival_multiplier(now))
                            .unwrap_or(1.0);
                        let next = now - (gap / mult) * (1.0 - rng.f64()).ln();
                        push(&mut calendar, &mut seq, next, Event::Arrival);
                    }
                }
                Event::Decision => {
                    report.decisions += 1;
                    // Scenario events apply at frame boundaries, before
                    // the drain — the scheduler sees the mutated world.
                    let apply_w0 = obs.map(|_| wall_t0.elapsed().as_secs_f64() * 1e3);
                    let events_applied = match engine.as_mut() {
                        Some(e) => e.advance_traced(now, &mut topology, &mut placement, obs),
                        None => 0,
                    };
                    if let Some(r) = obs {
                        let t0 = apply_w0.unwrap_or(0.0);
                        let t1 = wall_t0.elapsed().as_secs_f64() * 1e3;
                        r.span("des", "frame.apply", PID_WALL, 0, t0, t1 - t0, report.decisions);
                    }
                    let queue_depth: u64 = queues.iter().map(|q| q.len() as u64).sum();
                    for q in &queues {
                        report.queue_len.push(q.len() as f64);
                    }
                    let drain_w0 = obs.map(|_| wall_t0.elapsed().as_secs_f64() * 1e3);
                    scratch.drained.clear();
                    for (pos, q) in queues.iter_mut().enumerate() {
                        q.drain_with(now, |p, tq| scratch.drained.push((pos, p, tq)));
                    }
                    if let Some(r) = obs {
                        let t0 = drain_w0.unwrap_or(0.0);
                        let t1 = wall_t0.elapsed().as_secs_f64() * 1e3;
                        r.span("des", "frame.drain", PID_WALL, 0, t0, t1 - t0, report.decisions);
                    }
                    let mut decided = None;
                    if !scratch.drained.is_empty() {
                        decided = self.decide(
                            now,
                            &topology,
                            &catalog,
                            &placement,
                            &edges,
                            &mut busy,
                            &mut rng,
                            &mut report,
                            &mut calendar,
                            &mut seq,
                            &mut push,
                            &mut scratch,
                            obs.is_some(),
                            reference,
                        );
                    }
                    // Per-frame sample, after the decision committed its
                    // capacity so utilization reflects the new in-service
                    // work.
                    let live_gamma: f64 = topology
                        .servers
                        .iter()
                        .filter(|s| s.up)
                        .map(|s| s.gamma)
                        .sum();
                    let busy_live: f64 = topology
                        .servers
                        .iter()
                        .enumerate()
                        .filter(|(_, s)| s.up)
                        .map(|(j, _)| busy[j])
                        .sum();
                    report.frames.push(FrameSample {
                        t_ms: now,
                        generated: report.generated,
                        served: report.served,
                        satisfied: report.satisfied,
                        dropped: report.dropped,
                        rejected: report.rejected_at_queue,
                        local: report.local,
                        cloud: report.cloud,
                        peer: report.peer,
                        queue_depth,
                        capacity_utilization: if live_gamma > 0.0 {
                            busy_live / live_gamma
                        } else {
                            0.0
                        },
                        events_applied,
                    });
                    if let Some(r) = obs {
                        r.sample("edgeus_des_queue_depth", PID_VIRTUAL, 0, now, queue_depth as f64);
                        r.sample(
                            "edgeus_des_calendar_depth",
                            PID_VIRTUAL,
                            0,
                            now,
                            calendar.len() as f64,
                        );
                        let mut fe = FrameExplain {
                            t_ms: now,
                            decision: report.decisions,
                            calendar_depth: calendar.len() as u64,
                            events_applied,
                            ..FrameExplain::default()
                        };
                        if let Some((inst, wall_us)) = &decided {
                            let ex = explain_schedule(inst, &scratch.schedule);
                            fe.requests = scratch.schedule.slots.len() as u64;
                            fe.served = scratch.schedule.served() as u64;
                            fe.candidates_considered = ex.candidates_considered;
                            fe.drop_deadline_infeasible = ex.drops(DropReason::DeadlineInfeasible);
                            fe.drop_capacity_exhausted = ex.drops(DropReason::CapacityExhausted);
                            fe.drop_server_down = ex.drops(DropReason::ServerDown);
                            fe.drop_policy = ex.drops(DropReason::Policy);
                            fe.schedule_wall_us = *wall_us;
                            r.add("edgeus_des_candidates_total", ex.candidates_considered as f64);
                            for (oc, (edge_pos, p, tq)) in
                                ex.outcomes.iter().zip(scratch.drained.iter())
                            {
                                let track = edges[*edge_pos].0 as u32;
                                match oc.outcome {
                                    Outcome::Served { server, offloaded, .. } => {
                                        let kind = if !offloaded {
                                            "local"
                                        } else if inst.topology.servers[server].is_cloud() {
                                            "cloud"
                                        } else {
                                            "peer"
                                        };
                                        r.span("des", "queue", PID_VIRTUAL, track, p.arrival_ms, *tq, p.id);
                                        r.add_labeled("edgeus_des_assigned_total", "kind", kind, 1.0);
                                    }
                                    Outcome::Dropped(reason) => {
                                        let label = reason.as_str();
                                        r.add_labeled("edgeus_des_dropped_total", "reason", label, 1.0);
                                        r.instant("des", "drop", PID_VIRTUAL, track, now, label, p.id);
                                    }
                                }
                            }
                        }
                        report.explain.push(fe);
                    }
                    // Recover the pooled buffers lent to an observed
                    // frame's instance (the unobserved path gives them
                    // back inside `decide`).
                    if let Some((inst, _)) = decided {
                        let (requests, residual) = inst.into_buffers();
                        scratch.requests = requests;
                        if let Some(r) = residual {
                            scratch.residual_gamma = r;
                        }
                    }
                    // Next frame while work can still arrive or drain.
                    if now < self.cfg.horizon_ms + 10.0 * self.cfg.frame_ms {
                        push(
                            &mut calendar,
                            &mut seq,
                            now + self.cfg.frame_ms,
                            Event::Decision,
                        );
                    }
                }
                Event::Completion {
                    server,
                    comp_cost,
                    accuracy,
                    a_min,
                    c_max,
                    arrival_ms,
                    kind,
                    id,
                } => {
                    busy[server] -= comp_cost;
                    let total = now - arrival_ms;
                    report.served += 1;
                    report.completion.push(total);
                    report.latency_hist.record(total);
                    match kind {
                        0 => report.local += 1,
                        1 => report.cloud += 1,
                        _ => report.peer += 1,
                    }
                    let ok = accuracy >= a_min && total <= c_max;
                    if ok {
                        report.satisfied += 1;
                    }
                    if let Some(r) = obs {
                        r.span("des", "serve", PID_VIRTUAL, server as u32, arrival_ms, total, id);
                        r.add("edgeus_des_served_total", 1.0);
                        if ok {
                            r.add("edgeus_des_satisfied_total", 1.0);
                        }
                    }
                }
            }
        }
        // lint:no-alloc:end
        // Harvest rank-cache accounting from the pooled scratch. The
        // reference path schedules through fresh per-frame scratch, so
        // its counters stay zero — which is fine: these fields are not
        // serialized, so pooled and reference dumps remain byte-equal.
        report.cache_hits = scratch.sched.rank_cache.hits;
        report.cache_misses = scratch.sched.rank_cache.misses;
        report.cache_rebuilds = scratch.sched.rank_cache.rebuilds;
        report
    }

    /// Run one decision frame over `scratch.drained`, leaving the
    /// schedule in `scratch.schedule`. Returns the instance and the
    /// policy's wall-clock µs when `obs_on` (for post-hoc explanation;
    /// the caller must recover the lent buffers via
    /// [`ProblemInstance::into_buffers`]); `None` otherwise, with the
    /// buffers already recovered, so the hot path allocates nothing.
    #[allow(clippy::too_many_arguments)]
    fn decide<'w>(
        &self,
        now: f64,
        topology: &'w Topology,
        catalog: &'w ServiceCatalog,
        placement: &'w Placement,
        edges: &[crate::model::ServerId],
        busy: &mut [f64],
        rng: &mut Rng,
        report: &mut DesReport,
        calendar: &mut BinaryHeap<Reverse<Entry>>,
        seq: &mut u64,
        push: &mut impl FnMut(&mut BinaryHeap<Reverse<Entry>>, &mut u64, f64, Event),
        scratch: &mut FrameScratch,
        obs_on: bool,
        reference: bool,
    ) -> Option<(ProblemInstance<'w>, f64)> {
        // lint:no-alloc:begin — per-frame decision: pooled buffers only.
        // The `reference` branch is the cold golden-oracle path and is
        // exempted line-by-line.
        let FrameScratch { drained, requests, residual_gamma, sched, schedule } = scratch;
        requests.clear();
        for (i, (edge_pos, p, tq)) in drained.iter().enumerate() {
            requests.push(
                Request::new(i, p.service.0, edges[*edge_pos].0)
                    .with_qos(p.a_min, p.c_max)
                    .with_queue_delay(*tq)
                    .with_payload(p.payload),
            );
        }
        let frame_requests = std::mem::take(requests);
        let max_cs = self.cfg.scenario.workload.max_completion_ms;
        let inst = if reference {
            // Golden-oracle path (pre-pooling semantics): deep-clone the
            // world and write the residual γ into the clone.
            let mut frame_topology = topology.clone(); // lint:allow(alloc)
            for (j, server) in frame_topology.servers.iter_mut().enumerate() {
                server.gamma = (server.gamma - busy[j]).max(0.0);
            }
            ProblemInstance::new(frame_topology, catalog.clone(), placement.clone(), frame_requests) // lint:allow(alloc)
                .with_normalization(100.0, max_cs)
        } else {
            // Hot path: borrow the live world; the frame's residual γ
            // (same float math: subtract in-service work, clamp at zero)
            // goes into the pooled side slice instead of a topology
            // clone. η needs no residual — it resets every frame.
            residual_gamma.clear();
            for (j, server) in topology.servers.iter().enumerate() {
                residual_gamma.push((server.gamma - busy[j]).max(0.0));
            }
            ProblemInstance::borrowed(topology, catalog, placement, frame_requests)
                .with_residual_gamma(std::mem::take(residual_gamma))
                .with_normalization(100.0, max_cs)
        };
        let sched_t0 = if obs_on { Some(Instant::now()) } else { None };
        if reference {
            *schedule = self.scheduler.schedule(&inst, rng);
        } else {
            self.scheduler.schedule_into(&inst, rng, sched, schedule);
        }
        let schedule_wall_us = sched_t0.map_or(0.0, |t| t.elapsed().as_secs_f64() * 1e6);

        for (i, (_, p, tq)) in drained.iter().enumerate() {
            match &schedule.slots[i] {
                None => report.dropped += 1,
                Some(a) => {
                    report.queue_delay.push(*tq);
                    let j = a.candidate.server.0;
                    busy[j] += a.candidate.comp_cost;
                    // Completion fires after comm + proc (T^q already
                    // elapsed in the queue).
                    let remaining = a.candidate.completion_ms - tq;
                    let kind = if !a.candidate.offloaded {
                        0
                    } else if inst.topology.server(a.candidate.server).is_cloud() {
                        1
                    } else {
                        2
                    };
                    push(
                        calendar,
                        seq,
                        now + remaining.max(0.0),
                        Event::Completion {
                            server: j,
                            comp_cost: a.candidate.comp_cost,
                            accuracy: a.candidate.accuracy_pct,
                            a_min: p.a_min,
                            c_max: p.c_max,
                            arrival_ms: p.arrival_ms,
                            kind,
                            id: p.id,
                        },
                    );
                }
            }
        }
        if obs_on {
            Some((inst, schedule_wall_us))
        } else {
            let (frame_requests, residual) = inst.into_buffers();
            *requests = frame_requests;
            if let Some(r) = residual {
                *residual_gamma = r;
            }
            None
        }
        // lint:no-alloc:end
    }
}

/// Sweep offered load for a set of policies (the DES analogue of the
/// testbed panels, in pure virtual time). Runs are independent per
/// (policy, rate) cell, so the grid fans out across worker threads;
/// results are order-stable regardless of thread count.
pub fn load_sweep(
    base: &DesConfig,
    policy_names: &[&str],
    rates_per_s: &[f64],
) -> crate::metrics::Series {
    let mut series = crate::metrics::Series::new(
        "offered load (req/s)",
        "satisfied users (%)",
        rates_per_s.to_vec(),
    );
    let nan = vec![f64::NAN; rates_per_s.len()];
    // Resolve every policy up front so an unknown name still panics
    // eagerly (same contract as the old serial loop).
    let policies: Vec<_> = policy_names
        .iter()
        .map(|name| crate::coordinator::scheduler_by_name(name).expect("unknown policy")) // lint:allow(unwrap) — caller passes names from the vetted policy list
        .collect();
    let mut jobs: Vec<(usize, f64)> = Vec::with_capacity(policies.len() * rates_per_s.len());
    for pi in 0..policies.len() {
        for &rate in rates_per_s {
            jobs.push((pi, rate));
        }
    }
    let threads = crate::sim::montecarlo::default_threads();
    let ys = crate::benchkit::parallel_map(&jobs, threads, |_, &(pi, rate)| {
        let mut cfg = base.clone();
        cfg.arrival_rate_per_s = rate;
        Des::new(cfg, policies[pi].as_ref()).run().satisfied_pct()
    });
    for (pi, name) in policy_names.iter().enumerate() {
        let row = ys[pi * rates_per_s.len()..(pi + 1) * rates_per_s.len()].to_vec();
        series.push_policy(name, row, nan.clone());
    }
    series
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::gus::Gus;
    use crate::model::service::CatalogParams;
    use crate::model::topology::TopologyParams;

    fn quick_cfg(rate: f64) -> DesConfig {
        DesConfig {
            scenario: ScenarioParams {
                topology: TopologyParams { num_edge: 3, num_cloud: 1, ..Default::default() },
                catalog: CatalogParams { num_services: 10, num_tiers: 4, ..Default::default() },
                workload: WorkloadParams {
                    deadline_mean_ms: 4000.0,
                    deadline_std_ms: 2000.0,
                    ..Default::default()
                },
            },
            horizon_ms: 30_000.0,
            arrival_rate_per_s: rate,
            ..Default::default()
        }
    }

    #[test]
    fn conservation_every_request_accounted() {
        let gus = Gus::default();
        let r = Des::new(quick_cfg(3.0), &gus).run();
        assert!(r.generated > 0);
        assert_eq!(
            r.generated,
            r.served + r.dropped + r.rejected_at_queue,
            "conservation: {r:?}"
        );
        assert_eq!(r.served, r.local + r.cloud + r.peer);
        assert!(r.satisfied <= r.served);
        r.check_conservation().unwrap();
    }

    #[test]
    fn pooled_run_matches_reference_byte_for_byte() {
        // The allocation-free hot path must be decision-for-decision
        // identical to the pre-pooling clone-the-world oracle.
        let gus = Gus::default();
        for rate in [3.0, 150.0] {
            let pooled = Des::new(quick_cfg(rate), &gus).run().to_json().dump();
            let reference = Des::new(quick_cfg(rate), &gus).run_reference().to_json().dump();
            assert_eq!(pooled, reference, "divergence at rate {rate}");
        }
    }

    #[test]
    fn steady_state_rank_cache_hits_dominate() {
        // Plain world (no scenario events): after the first touch of each
        // (covering, service) class, every later frame must be warm.
        let gus = Gus::default();
        let r = Des::new(quick_cfg(150.0), &gus).run();
        let lookups = r.cache_hits + r.cache_misses;
        assert!(lookups > 0, "cached GUS must account lookups");
        assert!(
            r.cache_hit_rate() > 0.9,
            "steady-state hit rate {:.3} ({} hits / {} lookups)",
            r.cache_hit_rate(),
            r.cache_hits,
            lookups
        );
        assert!(r.cache_rebuilds <= r.cache_misses);
        // The uncached oracle keeps no cache at all.
        let nocache = Gus::default().uncached();
        let r0 = Des::new(quick_cfg(150.0), &nocache).run();
        assert_eq!(r0.cache_hits + r0.cache_misses, 0);
        assert_eq!(r.to_json().dump(), r0.to_json().dump(), "cache must not change output");
    }

    #[test]
    fn deterministic_per_seed() {
        let gus = Gus::default();
        let a = Des::new(quick_cfg(3.0), &gus).run();
        let b = Des::new(quick_cfg(3.0), &gus).run();
        assert_eq!(a.generated, b.generated);
        assert_eq!(a.satisfied, b.satisfied);
        assert_eq!(a.mix_pct(), b.mix_pct());
    }

    #[test]
    fn seeds_differ() {
        let gus = Gus::default();
        let a = Des::new(quick_cfg(3.0), &gus).run();
        let mut cfg = quick_cfg(3.0);
        cfg.seed = 99;
        let b = Des::new(cfg, &gus).run();
        assert_ne!((a.generated, a.satisfied), (b.generated, b.satisfied));
    }

    #[test]
    fn load_pressure_reduces_satisfaction() {
        let gus = Gus::default();
        // Queue-full decisions keep admission rejection at zero (draining
        // is instantaneous in virtual time), so overload shows up as
        // scheduler drops, not queue rejections.
        let light = Des::new(quick_cfg(3.0), &gus).run();
        let heavy = Des::new(quick_cfg(150.0), &gus).run();
        assert!(
            heavy.satisfied_pct() < light.satisfied_pct() - 10.0,
            "light {:.1}% vs heavy {:.1}%",
            light.satisfied_pct(),
            heavy.satisfied_pct()
        );
        assert!(heavy.dropped > light.dropped);
    }

    #[test]
    fn queue_delay_bounded_by_frame_plus_slack() {
        let gus = Gus::default();
        let r = Des::new(quick_cfg(4.0), &gus).run();
        // Every admitted request waits at most one frame (decisions also
        // fire on queue-full).
        assert!(r.queue_delay.max() <= 3000.0 + 1e-6, "{}", r.queue_delay.max());
        assert!(r.queue_delay.count() > 0);
    }

    #[test]
    fn completions_release_capacity() {
        // If capacity leaked, a long run would converge to 0 served.
        let gus = Gus::default();
        let mut cfg = quick_cfg(3.0);
        cfg.horizon_ms = 90_000.0;
        let r = Des::new(cfg, &gus).run();
        let last_third_floor = r.served as f64 / r.generated as f64;
        assert!(last_third_floor > 0.2, "throughput collapsed: {r:?}");
    }

    #[test]
    fn frames_series_recorded_and_monotone() {
        let gus = Gus::default();
        let r = Des::new(quick_cfg(3.0), &gus).run();
        assert!(!r.frames.is_empty(), "every decision must sample a frame");
        for w in r.frames.windows(2) {
            assert!(w[0].t_ms <= w[1].t_ms);
            assert!(w[0].generated <= w[1].generated);
            assert!(w[0].satisfied <= w[1].satisfied);
            assert!(w[0].served <= w[1].served);
        }
        let last = r.frames.last().unwrap();
        assert_eq!(last.generated, r.generated, "final frame sees every arrival");
        assert_eq!(last.events_applied, 0, "no script, no events");
    }

    #[test]
    fn report_json_dump_is_deterministic_and_parseable() {
        let gus = Gus::default();
        let a = Des::new(quick_cfg(3.0), &gus).run().to_json().dump();
        let b = Des::new(quick_cfg(3.0), &gus).run().to_json().dump();
        assert_eq!(a, b);
        assert!(crate::util::json::Json::parse(&a).is_ok(), "dump must be valid JSON");
    }

    #[test]
    fn load_sweep_produces_monotone_series_for_gus() {
        let base = quick_cfg(1.0);
        let series = load_sweep(&base, &["gus", "local-all"], &[3.0, 150.0]);
        assert_eq!(series.policies.len(), 2);
        let gus = &series.policies[0].1;
        assert!(gus[1] <= gus[0] + 1e-9);
    }

    #[test]
    fn disabled_recorder_keeps_report_byte_identical() {
        let gus = Gus::default();
        let plain = Des::new(quick_cfg(3.0), &gus).run();
        let rec = Recorder::disabled();
        let with_disabled = Des::new(quick_cfg(3.0), &gus).with_recorder(&rec).run();
        assert!(with_disabled.explain.is_empty());
        assert_eq!(rec.total_events(), 0);
        assert_eq!(plain.to_json().dump(), with_disabled.to_json().dump());
    }

    #[test]
    fn enabled_recorder_does_not_change_outcomes_and_explains_frames() {
        let gus = Gus::default();
        let plain = Des::new(quick_cfg(150.0), &gus).run();
        let rec = Recorder::enabled(1 << 14);
        let traced = Des::new(quick_cfg(150.0), &gus).with_recorder(&rec).run();
        // Observation must not perturb the simulation.
        assert_eq!(plain.generated, traced.generated);
        assert_eq!(plain.served, traced.served);
        assert_eq!(plain.satisfied, traced.satisfied);
        assert_eq!(plain.dropped, traced.dropped);
        assert_eq!(plain.rejected_at_queue, traced.rejected_at_queue);
        // One explanation per decision, and reasons account for every
        // scheduler drop.
        assert_eq!(traced.explain.len(), traced.decisions as usize);
        let explained_drops: u64 = traced.explain.iter().map(|e| e.total_drops()).sum();
        assert_eq!(explained_drops, traced.dropped);
        let explained_served: u64 = traced.explain.iter().map(|e| e.served).sum();
        assert_eq!(explained_served, traced.served);
        // Recorder counters agree with the report.
        assert_eq!(
            rec.counter_value("edgeus_des_generated_total", "", "") as u64,
            traced.generated
        );
        assert_eq!(
            rec.counter_value("edgeus_des_served_total", "", "") as u64,
            traced.served
        );
        assert_eq!(
            rec.counter_value(
                "edgeus_des_dropped_total",
                "reason",
                DropReason::QueueFull.as_str()
            ) as u64,
            traced.rejected_at_queue
        );
        let scheduler_drops: f64 = DropReason::ALL
            .iter()
            .filter(|r| **r != DropReason::QueueFull)
            .map(|r| rec.counter_value("edgeus_des_dropped_total", "reason", r.as_str()))
            .sum();
        assert_eq!(scheduler_drops as u64, traced.dropped);
        // The instrumented report serializes with an explain block.
        let dump = traced.to_json().dump();
        assert!(dump.contains("\"explain\""));
        assert!(!traced.explain_markdown().is_empty());
    }
}
