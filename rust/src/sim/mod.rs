//! Simulation substrate: admission queueing / decision frames and the
//! Monte-Carlo harness behind the paper's numerical results (Fig. 1 a–d).

pub mod des;
pub mod montecarlo;
pub mod queueing;

pub use des::{Des, DesConfig, DesReport, FrameExplain, FrameSample};
pub use montecarlo::{MonteCarlo, PolicyStats};
pub use queueing::{AdmissionQueue, FrameClock};
