//! Property-testing substrate (the offline registry has no proptest).
//!
//! `check` runs a property over `cases` seeded inputs; on failure it
//! reruns with progressively simpler size hints to report the smallest
//! failing case it can find, then panics with the reproducing seed.
//!
//! ```ignore
//! prop::check(200, |g| {
//!     let n = g.usize_in(1..50);
//!     let xs = g.vec_f64(n, 0.0..100.0);
//!     assert!(xs.iter().all(|x| *x >= 0.0));
//! });
//! ```

use crate::util::rng::Rng;
use std::ops::Range;

/// Random input source handed to properties; wraps [`Rng`] with
/// size-bounded convenience generators.
pub struct Gen {
    rng: Rng,
    /// 0.0..=1.0 multiplier applied to collection/size hints while
    /// searching for a smaller failing case.
    size_scale: f64,
    pub seed: u64,
}

impl Gen {
    fn new(seed: u64, size_scale: f64) -> Gen {
        Gen { rng: Rng::new(seed), size_scale, seed }
    }

    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn f64_in(&mut self, r: Range<f64>) -> f64 {
        self.rng.uniform(r.start, r.end)
    }

    /// Size-scaled integer range: shrink passes sample nearer `r.start`.
    pub fn usize_in(&mut self, r: Range<usize>) -> usize {
        let span = r.end.saturating_sub(r.start).max(1);
        let scaled = ((span as f64 * self.size_scale).ceil() as usize).clamp(1, span);
        r.start + self.rng.index(scaled)
    }

    pub fn u64_in(&mut self, r: Range<u64>) -> u64 {
        r.start + self.rng.below((r.end - r.start).max(1))
    }

    pub fn vec_f64(&mut self, n: usize, r: Range<f64>) -> Vec<f64> {
        (0..n).map(|_| self.f64_in(r.clone())).collect()
    }

    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        self.rng.normal(mean, std)
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty(), "pick from empty slice");
        &xs[self.rng.index(xs.len())]
    }
}

/// Run `property` on `cases` random inputs. Panics (with seed) on failure.
pub fn check<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(cases: u64, property: F) {
    // Base seed is overridable for reproducing CI failures.
    let base = std::env::var("PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xED6E05u64);
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x9E3779B97F4A7C15));
        if run_case(&property, seed, 1.0).is_err() {
            // Shrink-lite: retry the same seed with smaller size hints to
            // report a simpler failure if one exists.
            for scale in [0.1, 0.25, 0.5] {
                if let Err(msg) = run_case(&property, seed, scale) {
                    panic!(
                        "property failed (seed={seed}, size_scale={scale}): {msg}\n\
                         reproduce with PROP_SEED={base} (case {case})"
                    );
                }
            }
            let msg = run_case(&property, seed, 1.0).unwrap_err();
            panic!(
                "property failed (seed={seed}): {msg}\n\
                 reproduce with PROP_SEED={base} (case {case})"
            );
        }
    }
}

fn run_case<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(
    property: &F,
    seed: u64,
    scale: f64,
) -> Result<(), String> {
    let result = std::panic::catch_unwind(|| {
        let mut g = Gen::new(seed, scale);
        property(&mut g);
    });
    match result {
        Ok(()) => Ok(()),
        Err(e) => {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            Err(msg)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(50, |g| {
            let n = g.usize_in(1..20);
            let xs = g.vec_f64(n, 0.0..1.0);
            assert_eq!(xs.len(), n);
            assert!(xs.iter().all(|x| (0.0..1.0).contains(x)));
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_reports_seed() {
        check(50, |g| {
            let x = g.f64_in(0.0..10.0);
            assert!(x < 9.0, "x too large: {x}");
        });
    }

    #[test]
    fn usize_in_respects_bounds() {
        check(100, |g| {
            let v = g.usize_in(3..10);
            assert!((3..10).contains(&v));
        });
    }

    #[test]
    fn deterministic_given_env_seed() {
        let mut a = Gen::new(99, 1.0);
        let mut b = Gen::new(99, 1.0);
        assert_eq!(a.u64_in(0..1000), b.u64_in(0..1000));
    }
}
