//! Statistics substrate: online accumulators, percentiles, confidence
//! intervals, and fixed-bucket histograms — used by the Monte-Carlo
//! harness, the metrics layer, and the in-tree bench harness.

/// Welford online mean/variance accumulator.
#[derive(Clone, Debug, Default)]
pub struct Accumulator {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Accumulator {
    pub fn new() -> Self {
        Accumulator { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn merge(&mut self, other: &Accumulator) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        self.m2 += other.m2 + d * d * (self.n as f64 * other.n as f64) / n as f64;
        self.mean += d * other.n as f64 / n as f64;
        self.n = n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    /// Sample variance (n-1 denominator).
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Half-width of the ~95% CI on the mean (normal approximation).
    pub fn ci95(&self) -> f64 {
        if self.n < 2 {
            return f64::NAN;
        }
        1.96 * self.std() / (self.n as f64).sqrt()
    }
}

/// Percentile of a sample (linear interpolation, `q` in [0,1]).
/// Sorts a copy; fine for the sample sizes we report on.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let pos = q.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

/// Fixed-bucket latency histogram (log-ish bounds chosen by caller).
/// `Default` gives an exponential 1 ms..32 s ladder.
#[derive(Clone, Debug)]
pub struct Histogram {
    bounds: Vec<f64>,
    counts: Vec<u64>,
    acc: Accumulator,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::exponential(1.0, 2.0, 16)
    }
}

impl Histogram {
    /// `bounds` are upper edges; an extra overflow bucket is appended.
    pub fn new(bounds: Vec<f64>) -> Self {
        let n = bounds.len() + 1;
        Histogram { bounds, counts: vec![0; n], acc: Accumulator::new() }
    }

    /// Convenience: exponential bounds `start, start*factor, ...` (n of them).
    pub fn exponential(start: f64, factor: f64, n: usize) -> Self {
        let mut bounds = Vec::with_capacity(n);
        let mut b = start;
        for _ in 0..n {
            bounds.push(b);
            b *= factor;
        }
        Histogram::new(bounds)
    }

    pub fn record(&mut self, x: f64) {
        let idx = self.bounds.partition_point(|b| *b < x);
        self.counts[idx] += 1;
        self.acc.push(x);
    }

    pub fn count(&self) -> u64 {
        self.acc.count()
    }

    pub fn mean(&self) -> f64 {
        self.acc.mean()
    }

    pub fn max(&self) -> f64 {
        self.acc.max()
    }

    /// Approximate quantile from bucket boundaries.
    pub fn quantile(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return f64::NAN;
        }
        let target = (q.clamp(0.0, 1.0) * total as f64).ceil() as u64;
        let mut cum = 0;
        for (i, c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= target.max(1) {
                return if i < self.bounds.len() { self.bounds[i] } else { self.acc.max() };
            }
        }
        self.acc.max()
    }

    pub fn buckets(&self) -> impl Iterator<Item = (f64, u64)> + '_ {
        self.bounds
            .iter()
            .copied()
            .chain(std::iter::once(f64::INFINITY))
            .zip(self.counts.iter().copied())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulator_basic() {
        let mut a = Accumulator::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            a.push(x);
        }
        assert_eq!(a.count(), 4);
        assert!((a.mean() - 2.5).abs() < 1e-12);
        assert!((a.var() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(a.min(), 1.0);
        assert_eq!(a.max(), 4.0);
    }

    #[test]
    fn accumulator_merge_equals_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut whole = Accumulator::new();
        xs.iter().for_each(|x| whole.push(*x));
        let mut a = Accumulator::new();
        let mut b = Accumulator::new();
        xs[..40].iter().for_each(|x| a.push(*x));
        xs[40..].iter().for_each(|x| b.push(*x));
        a.merge(&b);
        assert!((a.mean() - whole.mean()).abs() < 1e-9);
        assert!((a.var() - whole.var()).abs() < 1e-9);
    }

    #[test]
    fn empty_accumulator_nan_mean() {
        assert!(Accumulator::new().mean().is_nan());
    }

    #[test]
    fn ci_shrinks_with_n() {
        let mut small = Accumulator::new();
        let mut big = Accumulator::new();
        for i in 0..10 {
            small.push(i as f64 % 3.0);
        }
        for i in 0..10_000 {
            big.push(i as f64 % 3.0);
        }
        assert!(big.ci95() < small.ci95());
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 4.0);
        assert!((percentile(&xs, 0.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_empty_nan() {
        assert!(percentile(&[], 0.5).is_nan());
    }

    #[test]
    fn histogram_counts_and_quantiles() {
        let mut h = Histogram::new(vec![10.0, 100.0, 1000.0]);
        for x in [1.0, 5.0, 50.0, 500.0, 5000.0] {
            h.record(x);
        }
        assert_eq!(h.count(), 5);
        let counts: Vec<u64> = h.buckets().map(|(_, c)| c).collect();
        assert_eq!(counts, vec![2, 1, 1, 1]);
        assert!(h.quantile(0.2) <= 10.0);
        assert_eq!(h.quantile(1.0), 5000.0);
    }

    #[test]
    fn histogram_exponential_bounds() {
        let h = Histogram::exponential(1.0, 10.0, 3);
        let bounds: Vec<f64> = h.buckets().map(|(b, _)| b).collect();
        assert_eq!(bounds[..3], [1.0, 10.0, 100.0]);
        assert!(bounds[3].is_infinite());
    }

    #[test]
    fn histogram_quantile_empty_is_nan() {
        let h = Histogram::new(vec![10.0, 100.0]);
        for q in [0.0, 0.5, 1.0] {
            assert!(h.quantile(q).is_nan(), "q={q}");
        }
    }

    #[test]
    fn histogram_quantile_q0_is_first_nonempty_bucket() {
        // q = 0 gives target 0, bumped to 1 — the first occupied bucket.
        let mut h = Histogram::new(vec![10.0, 100.0, 1000.0]);
        h.record(50.0);
        h.record(60.0);
        assert_eq!(h.quantile(0.0), 100.0);
    }

    #[test]
    fn histogram_quantile_q1_is_containing_bucket_bound() {
        // q = 1 resolves to the upper bound of the bucket holding the
        // last record — not the exact max — unless the mass overflows.
        let mut h = Histogram::new(vec![10.0, 100.0]);
        h.record(3.0);
        h.record(7.0);
        assert_eq!(h.quantile(1.0), 10.0);
    }

    #[test]
    fn histogram_quantile_overflow_bucket_returns_max() {
        let mut h = Histogram::new(vec![10.0]);
        h.record(5000.0);
        assert_eq!(h.quantile(0.5), 5000.0);
        assert_eq!(h.quantile(1.0), 5000.0);
    }

    #[test]
    fn histogram_quantile_clamps_out_of_range_q() {
        let mut h = Histogram::new(vec![10.0, 100.0, 1000.0]);
        h.record(5.0);
        h.record(500.0);
        assert_eq!(h.quantile(-3.0), h.quantile(0.0));
        assert_eq!(h.quantile(7.0), h.quantile(1.0));
    }

    #[test]
    fn histogram_quantile_single_bucket_mass_is_flat() {
        // All mass in one bucket: every quantile is that bucket's bound.
        let mut h = Histogram::new(vec![10.0, 100.0, 1000.0]);
        for _ in 0..5 {
            h.record(50.0);
        }
        for q in [0.0, 0.25, 0.5, 0.9, 1.0] {
            assert_eq!(h.quantile(q), 100.0, "q={q}");
        }
    }
}
