//! Tiny CLI argument parser substrate (the offline registry has no clap).
//!
//! Supports `subcommand --flag --key value --key=value positional` forms —
//! enough for the `edgeus` launcher, examples and bench binaries.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positionals: Vec<String>,
    flags: BTreeMap<String, String>,
}

const FLAG_SET: &str = "true";

impl Args {
    /// Parse from an explicit token list (first token = first *argument*,
    /// not the program name).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I, with_subcommand: bool) -> Args {
        let mut args = Args::default();
        let mut it = tokens.into_iter().peekable();
        if with_subcommand {
            if let Some(tok) = it.peek() {
                if !tok.starts_with('-') {
                    args.subcommand = it.next();
                }
            }
        }
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    if let Some(v) = it.next() {
                        args.flags.insert(stripped.to_string(), v);
                    }
                } else {
                    args.flags.insert(stripped.to_string(), FLAG_SET.to_string());
                }
            } else {
                args.positionals.push(tok);
            }
        }
        args
    }

    /// Parse the process command line.
    pub fn from_env(with_subcommand: bool) -> Args {
        Args::parse(std::env::args().skip(1), with_subcommand)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// Comma-separated list value.
    pub fn get_list(&self, name: &str) -> Option<Vec<String>> {
        self.get(name)
            .map(|s| s.split(',').filter(|p| !p.is_empty()).map(|p| p.to_string()).collect())
    }

    /// Comma-separated numeric list (`--rates 1,4,16`); entries that do
    /// not parse are dropped silently, matching `get_f64`'s leniency.
    pub fn get_f64_list(&self, name: &str) -> Option<Vec<f64>> {
        self.get_list(name)
            .map(|v| v.iter().filter_map(|s| s.parse().ok()).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<String> {
        s.split_whitespace().map(|t| t.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_and_flags() {
        // Note: a bare `--flag value` pair always binds (greedy); flags
        // intended as booleans must come last or use `--flag=true`.
        let a = Args::parse(toks("figure --id fig1a --runs=100 out.json --verbose"), true);
        assert_eq!(a.subcommand.as_deref(), Some("figure"));
        assert_eq!(a.get("id"), Some("fig1a"));
        assert_eq!(a.get_usize("runs", 0), 100);
        assert!(a.flag("verbose"));
        assert_eq!(a.positionals, vec!["out.json"]);
    }

    #[test]
    fn no_subcommand_mode() {
        let a = Args::parse(toks("pos1 --k v"), false);
        assert!(a.subcommand.is_none());
        assert_eq!(a.positionals, vec!["pos1"]);
        assert_eq!(a.get("k"), Some("v"));
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = Args::parse(toks("--a --b value"), true);
        assert!(a.flag("a"));
        assert_eq!(a.get("b"), Some("value"));
    }

    #[test]
    fn defaults() {
        let a = Args::parse(toks(""), true);
        assert_eq!(a.get_f64("x", 1.5), 1.5);
        assert_eq!(a.get_or("y", "d"), "d");
        assert!(!a.flag("z"));
    }

    #[test]
    fn list_values() {
        let a = Args::parse(toks("--tiers tiny,small,base"), true);
        assert_eq!(
            a.get_list("tiers").unwrap(),
            vec!["tiny".to_string(), "small".to_string(), "base".to_string()]
        );
    }

    #[test]
    fn f64_list_parses_and_drops_garbage() {
        let a = Args::parse(toks("--rates 1,4.5,x,16"), true);
        assert_eq!(a.get_f64_list("rates").unwrap(), vec![1.0, 4.5, 16.0]);
        assert!(a.get_f64_list("missing").is_none());
    }

    #[test]
    fn negative_number_as_value() {
        // `--x -5` : "-5" does not start with "--", so it is a value.
        let a = Args::parse(toks("--x -5"), true);
        assert_eq!(a.get_f64("x", 0.0), -5.0);
    }
}
