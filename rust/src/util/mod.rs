//! Shared substrates built in-tree for the offline environment:
//! PRNG, JSON, CLI parsing, statistics, and property testing.

pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
