//! Minimal JSON substrate (the offline registry has no serde/serde_json).
//!
//! Supports the full JSON grammar minus extensions: objects, arrays,
//! strings with escapes, numbers, booleans, null. Used for the artifact
//! `manifest.json` emitted by `python/compile/aot.py`, scenario config
//! files, and figure-harness result dumps.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Object keys are ordered (BTreeMap) so serialization
/// is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|n| *n >= 0.0).map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]` access; returns Null for missing keys / non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // -- construction helpers --------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    /// Serialize compactly.
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Serialize with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("invalid literal, expected {s}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // BMP only; surrogate pairs unsupported (not
                            // produced by our emitters).
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap(); // lint:allow(unwrap) — from_utf8 succeeded on a non-empty slice
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap(); // lint:allow(unwrap) — number span is pure ASCII by construction
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b"), &Json::Null);
        assert_eq!(v.get("c").as_str(), Some("x"));
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""a\nb\t\"q\" A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"q\" A"));
    }

    #[test]
    fn round_trip() {
        let src = r#"{"name":"edgenet_tiny_b1","batch":1,"shape":[1,32,32,3],"acc":40.5,"ok":true,"x":null}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, v2);
        let v3 = Json::parse(&v.pretty()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\":}").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn whitespace_tolerant() {
        let v = Json::parse(" \n\t{ \"a\" : [ 1 , 2 ] } \r\n").unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 2);
    }

    #[test]
    fn get_on_missing_returns_null() {
        let v = Json::parse("{}").unwrap();
        assert!(v.get("nope").is_null());
        assert!(Json::Num(1.0).get("x").is_null());
    }

    #[test]
    fn integer_formatting_stable() {
        assert_eq!(Json::Num(8.0).dump(), "8");
        assert_eq!(Json::Num(8.5).dump(), "8.5");
    }

    #[test]
    fn unicode_pass_through() {
        let v = Json::parse("\"héllo ≥ 50%\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo ≥ 50%"));
        assert_eq!(Json::parse(&v.dump()).unwrap(), v);
    }
}
