//! Deterministic PRNG substrate (the offline registry has no `rand`).
//!
//! xoshiro256++ seeded via SplitMix64 — the standard pairing recommended by
//! the xoshiro authors. Every stochastic component in the crate (workload
//! generation, Monte-Carlo, the Random-Assignment baseline, simulated
//! channel jitter) draws from this generator, so a run is fully
//! reproducible from a single `u64` seed.

/// xoshiro256++ generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second output of the Box-Muller pair.
    gauss_spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed deterministically from a single value.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent stream (for parallel Monte-Carlo workers).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[0, n)` (Lemire's unbiased method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize index in `[0, n)`.
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Standard normal via Box-Muller (second value cached).
    pub fn gauss(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        // Avoid ln(0).
        let u1 = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with the given mean / standard deviation.
    #[inline]
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.gauss()
    }

    /// Normal clamped into `[lo, hi]` (the paper's truncated thresholds).
    pub fn normal_clamped(&mut self, mean: f64, std: f64, lo: f64, hi: f64) -> f64 {
        self.normal(mean, std).clamp(lo, hi)
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> Option<&'a T> {
        if xs.is_empty() {
            None
        } else {
            Some(&xs[self.index(xs.len())])
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher-Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.index(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_reasonable() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform(10.0, 20.0)).sum::<f64>() / n as f64;
        assert!((mean - 15.0).abs() < 0.05, "mean={mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(13);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::new(17);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gauss()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn normal_clamped_respects_bounds() {
        let mut r = Rng::new(19);
        for _ in 0..10_000 {
            let x = r.normal_clamped(45.0, 10.0, 0.0, 100.0);
            assert!((0.0..=100.0).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(29);
        let s = r.sample_indices(100, 10);
        assert_eq!(s.len(), 10);
        let mut d = s.clone();
        d.sort();
        d.dedup();
        assert_eq!(d.len(), 10);
    }

    #[test]
    fn sample_indices_k_exceeds_n() {
        let mut r = Rng::new(31);
        let s = r.sample_indices(3, 10);
        assert_eq!(s.len(), 3);
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut base = Rng::new(5);
        let mut a = base.fork(0);
        let mut b = base.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn choose_none_on_empty() {
        let mut r = Rng::new(37);
        let empty: [u8; 0] = [];
        assert!(r.choose(&empty).is_none());
    }
}
