//! User requests: the N set. Each request arrives at its covering edge
//! server `s_i` carrying QoS thresholds (minimum accuracy `A_i`, deadline
//! `C_i`) and trade-off weights (w_a, w_c) — Definition II.1 of the paper.

use crate::model::server::ServerId;
use crate::model::service::ServiceId;

/// Index into `ProblemInstance::requests`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct RequestId(pub usize);

/// One user request (users and requests are interchangeable in the paper:
/// a user with several requests is modelled as several users).
#[derive(Clone, Debug)]
pub struct Request {
    pub id: RequestId,
    /// Requested service type k.
    pub service: ServiceId,
    /// Minimum required accuracy A_i (percent).
    pub min_accuracy_pct: f64,
    /// Maximum tolerable completion time C_i (ms).
    pub max_completion_ms: f64,
    /// Accuracy weight w_ai ∈ [0,1].
    pub w_accuracy: f64,
    /// Delay weight w_ci ∈ [0,1].
    pub w_completion: f64,
    /// Covering edge server s_i (where the request was submitted).
    pub covering: ServerId,
    /// Admission-control queuing delay T^q_{i s_i} already accrued (ms).
    pub queue_delay_ms: f64,
    /// Payload size (bytes) — drives communication delay on the serving
    /// path (one image per request, as in the paper's testbed).
    pub payload_bytes: u64,
    /// Scheduling priority (higher first) — the paper's future-work
    /// extension ("considering different priorities for the requests");
    /// 0 = best-effort default.
    pub priority: u8,
}

impl Request {
    /// Minimal constructor used by tests; production paths go through
    /// `workload::RequestGenerator`.
    pub fn new(id: usize, service: usize, covering: usize) -> Request {
        Request {
            id: RequestId(id),
            service: ServiceId(service),
            min_accuracy_pct: 45.0,
            max_completion_ms: 4000.0,
            w_accuracy: 1.0,
            w_completion: 1.0,
            covering: ServerId(covering),
            queue_delay_ms: 0.0,
            payload_bytes: 14_000, // ≈ a small JPEG, matches testbed images
            priority: 0,
        }
    }

    pub fn with_priority(mut self, priority: u8) -> Request {
        self.priority = priority;
        self
    }

    pub fn with_qos(mut self, min_accuracy_pct: f64, max_completion_ms: f64) -> Request {
        self.min_accuracy_pct = min_accuracy_pct;
        self.max_completion_ms = max_completion_ms;
        self
    }

    pub fn with_weights(mut self, w_accuracy: f64, w_completion: f64) -> Request {
        assert!((0.0..=1.0).contains(&w_accuracy) && (0.0..=1.0).contains(&w_completion));
        self.w_accuracy = w_accuracy;
        self.w_completion = w_completion;
        self
    }

    pub fn with_queue_delay(mut self, ms: f64) -> Request {
        self.queue_delay_ms = ms;
        self
    }

    pub fn with_payload(mut self, bytes: u64) -> Request {
        self.payload_bytes = bytes;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_sets_fields() {
        let r = Request::new(7, 3, 1)
            .with_qos(60.0, 2500.0)
            .with_weights(0.3, 0.9)
            .with_queue_delay(12.0)
            .with_payload(9000);
        assert_eq!(r.id, RequestId(7));
        assert_eq!(r.service, ServiceId(3));
        assert_eq!(r.covering, ServerId(1));
        assert_eq!(r.min_accuracy_pct, 60.0);
        assert_eq!(r.max_completion_ms, 2500.0);
        assert_eq!(r.w_accuracy, 0.3);
        assert_eq!(r.w_completion, 0.9);
        assert_eq!(r.queue_delay_ms, 12.0);
        assert_eq!(r.payload_bytes, 9000);
    }

    #[test]
    #[should_panic]
    fn weights_out_of_range_rejected() {
        let _ = Request::new(0, 0, 0).with_weights(1.5, 0.5);
    }
}
