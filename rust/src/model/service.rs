//! Service catalog: the K services, each with |L| DL-model tiers, and the
//! placement of model replicas on servers.
//!
//! A tier's profile is everything the scheduler consumes about a model:
//! provided accuracy `a_kl`, per-server-class processing delay
//! `T^proc_{jkl}`, computation cost `v_kl` and communication cost `u_kl`.
//! On the serving path each (service, tier) additionally maps to a real
//! compiled EdgeNet artifact (see `runtime::manifest`).

use crate::model::server::ServerClass;
use crate::util::rng::Rng;

/// Index of a service k ∈ K.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ServiceId(pub usize);

/// Index of a DL-model tier l ∈ L (ascending accuracy).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct TierId(pub usize);

/// Scheduler-visible profile of one (service, tier) model.
#[derive(Clone, Debug)]
pub struct TierProfile {
    /// Provided top-1 accuracy a_kl in percent.
    pub accuracy_pct: f64,
    /// Processing delay per server class (ms), indexed by
    /// `ServerClass::index()`.
    pub proc_ms: [f64; ServerClass::COUNT],
    /// Computation cost v_kl (γ units consumed while serving).
    pub comp_cost: f64,
    /// Communication cost u_kl (η units consumed at the covering server
    /// when the request is offloaded).
    pub comm_cost: f64,
    /// Model artifact size (bytes) — drives storage placement.
    pub model_bytes: u64,
}

/// Parameters for synthesizing a catalog that matches the paper's §IV
/// testbed measurements.
#[derive(Clone, Debug)]
pub struct CatalogParams {
    pub num_services: usize,
    pub num_tiers: usize,
    /// Edge processing-delay band for the *fastest* tier (ms); paper:
    /// 950–1300 measured for SqueezeNet on an RP4.
    pub edge_proc_lo_ms: f64,
    pub edge_proc_hi_ms: f64,
    /// Cloud processing delay for the *fastest* tier (ms); paper: 300
    /// measured for GoogleNet on the desktop "cloud".
    pub cloud_proc_ms: f64,
    /// Accuracy band covered by the tier ladder (percent).
    pub accuracy_lo_pct: f64,
    pub accuracy_hi_pct: f64,
    /// Multiplier applied per tier step to processing delay (costlier
    /// models run longer — the accuracy-time trade-off).
    pub tier_slowdown: f64,
    /// Extra γ units the top tier costs relative to the bottom tier
    /// (comp_cost = 1 + growth·frac). The paper's testbed charges one
    /// thread per request regardless of model, so the default is 0;
    /// the ablation bench sweeps it.
    pub tier_cost_growth: f64,
}

impl Default for CatalogParams {
    fn default() -> Self {
        CatalogParams {
            num_services: 100,
            num_tiers: 10,
            edge_proc_lo_ms: 950.0,
            edge_proc_hi_ms: 1300.0,
            cloud_proc_ms: 300.0,
            accuracy_lo_pct: 30.0,
            accuracy_hi_pct: 95.0,
            tier_slowdown: 1.08,
            tier_cost_growth: 0.0,
        }
    }
}

/// The catalog for all services.
#[derive(Clone, Debug)]
pub struct ServiceCatalog {
    pub num_services: usize,
    pub num_tiers: usize,
    /// `profiles[k][l]`.
    profiles: Vec<Vec<TierProfile>>,
}

impl ServiceCatalog {
    /// Synthesize a catalog per the paper's measured bands. Deterministic
    /// in `rng`.
    pub fn synthetic(params: &CatalogParams, rng: &mut Rng) -> ServiceCatalog {
        assert!(params.num_services > 0 && params.num_tiers > 0);
        let mut profiles = Vec::with_capacity(params.num_services);
        for _ in 0..params.num_services {
            let mut tiers = Vec::with_capacity(params.num_tiers);
            // Per-service base edge delay within the measured band.
            let base_edge = rng.uniform(params.edge_proc_lo_ms, params.edge_proc_hi_ms);
            let base_cloud = params.cloud_proc_ms * rng.uniform(0.9, 1.1);
            for l in 0..params.num_tiers {
                let frac = if params.num_tiers == 1 {
                    0.0
                } else {
                    l as f64 / (params.num_tiers - 1) as f64
                };
                // Accuracy rises with tier; add small per-service jitter.
                let acc = params.accuracy_lo_pct
                    + frac * (params.accuracy_hi_pct - params.accuracy_lo_pct)
                    + rng.uniform(-2.0, 2.0);
                let slow = params.tier_slowdown.powi(l as i32);
                // Edge classes: small slower than large (speed 1.15/1.0/0.85).
                let class_speed = [1.15, 1.0, 0.85];
                let mut proc = [0.0; ServerClass::COUNT];
                for (ci, speed) in class_speed.iter().enumerate() {
                    proc[ci] = base_edge * slow * speed;
                }
                proc[ServerClass::Cloud.index()] = base_cloud * slow;
                tiers.push(TierProfile {
                    accuracy_pct: acc.clamp(0.0, 100.0),
                    proc_ms: proc,
                    comp_cost: 1.0 + params.tier_cost_growth * frac,
                    comm_cost: 1.0, // one image forwarded per offload
                    model_bytes: (2_000_000.0 * (1.0 + 4.0 * frac)) as u64,
                });
            }
            profiles.push(tiers);
        }
        ServiceCatalog {
            num_services: params.num_services,
            num_tiers: params.num_tiers,
            profiles,
        }
    }

    /// Build from explicit profiles (used by the serving path where the
    /// tiers are the real compiled EdgeNet artifacts).
    pub fn from_profiles(profiles: Vec<Vec<TierProfile>>) -> ServiceCatalog {
        assert!(!profiles.is_empty());
        let num_tiers = profiles[0].len();
        assert!(num_tiers > 0);
        assert!(profiles.iter().all(|p| p.len() == num_tiers));
        ServiceCatalog { num_services: profiles.len(), num_tiers, profiles }
    }

    pub fn profile(&self, k: ServiceId, l: TierId) -> &TierProfile {
        &self.profiles[k.0][l.0]
    }

    pub fn services(&self) -> impl Iterator<Item = ServiceId> {
        (0..self.num_services).map(ServiceId)
    }

    pub fn tiers(&self) -> impl Iterator<Item = TierId> {
        (0..self.num_tiers).map(TierId)
    }

    /// Highest accuracy available anywhere in the catalog (`Max_as`).
    pub fn max_accuracy_pct(&self) -> f64 {
        self.profiles
            .iter()
            .flatten()
            .map(|p| p.accuracy_pct)
            .fold(0.0, f64::max)
    }
}

/// Which (service, tier) replicas each server holds.
#[derive(Clone, Debug)]
pub struct Placement {
    /// `on[j]` = sorted (k, l) pairs available on server j; the cloud
    /// entry holds everything (represented implicitly).
    on: Vec<Vec<(ServiceId, TierId)>>,
    cloud_has_all: Vec<bool>,
    /// Construction-time generation; services never mutated since
    /// construction report this value (see [`Placement::service_gen`]).
    base_gen: u64,
    /// Lazily grown per-service generation overrides, stamped by
    /// `place`/`evict` on actual mutation. Keeping the vector lazily
    /// sized means an unmutated placement costs no per-service storage.
    service_gens: Vec<u64>,
}

impl Placement {
    /// Random storage-constrained placement (paper §IV: "services are
    /// randomly placed on the edge servers based on their associated
    /// storage capacity"); the cloud holds every model.
    pub fn random(
        catalog: &ServiceCatalog,
        classes: &[ServerClass],
        rng: &mut Rng,
    ) -> Placement {
        let mut on = Vec::with_capacity(classes.len());
        let mut cloud_has_all = Vec::with_capacity(classes.len());
        // All (k,l) pairs, shuffled per server.
        let all: Vec<(ServiceId, TierId)> = (0..catalog.num_services)
            .flat_map(|k| (0..catalog.num_tiers).map(move |l| (ServiceId(k), TierId(l))))
            .collect();
        for &class in classes {
            if class.is_cloud() {
                on.push(Vec::new());
                cloud_has_all.push(true);
                continue;
            }
            let slots = class.default_storage_slots();
            let mut mine = all.clone();
            rng.shuffle(&mut mine);
            mine.truncate(slots.min(mine.len()));
            mine.sort();
            on.push(mine);
            cloud_has_all.push(false);
        }
        Placement {
            on,
            cloud_has_all,
            base_gen: crate::model::topology::next_world_gen(),
            service_gens: Vec::new(),
        }
    }

    /// Place everything everywhere (used by unit tests / Happy scenarios).
    pub fn full(catalog: &ServiceCatalog, num_servers: usize) -> Placement {
        let all: Vec<(ServiceId, TierId)> = (0..catalog.num_services)
            .flat_map(|k| (0..catalog.num_tiers).map(move |l| (ServiceId(k), TierId(l))))
            .collect();
        Placement {
            on: vec![all; num_servers],
            cloud_has_all: vec![false; num_servers],
            base_gen: crate::model::topology::next_world_gen(),
            service_gens: Vec::new(),
        }
    }

    /// Explicit placement (serving path: the artifacts actually loaded).
    pub fn explicit(on: Vec<Vec<(ServiceId, TierId)>>, cloud_has_all: Vec<bool>) -> Placement {
        Placement {
            on,
            cloud_has_all,
            base_gen: crate::model::topology::next_world_gen(),
            service_gens: Vec::new(),
        }
    }

    pub fn has(&self, server: usize, k: ServiceId, l: TierId) -> bool {
        if self.cloud_has_all[server] {
            return true;
        }
        self.on[server].binary_search(&(k, l)).is_ok()
    }

    /// Tiers of service k available on `server`, ascending.
    pub fn tiers_of(&self, server: usize, k: ServiceId, num_tiers: usize) -> Vec<TierId> {
        if self.cloud_has_all[server] {
            return (0..num_tiers).map(TierId).collect();
        }
        self.on[server]
            .iter()
            .filter(|(kk, _)| *kk == k)
            .map(|(_, l)| *l)
            .collect()
    }

    /// Visit the tiers of service k available on `server`, ascending,
    /// without allocating — the hot-path form of [`Self::tiers_of`]
    /// (candidate enumeration calls this once per request per server).
    #[inline]
    pub fn for_each_tier(
        &self,
        server: usize,
        k: ServiceId,
        num_tiers: usize,
        mut f: impl FnMut(TierId),
    ) {
        if self.cloud_has_all[server] {
            for l in 0..num_tiers {
                f(TierId(l));
            }
            return;
        }
        for (kk, l) in self.on[server].iter() {
            if *kk == k {
                f(*l);
            }
        }
    }

    /// Add one (service, tier) replica on `server` (idempotent). On a
    /// cloud-has-all server this is a no-op: it already holds everything.
    /// Used by the scenario engine's `PlacementChange` events.
    pub fn place(&mut self, server: usize, k: ServiceId, l: TierId) {
        if self.cloud_has_all[server] {
            return;
        }
        if let Err(pos) = self.on[server].binary_search(&(k, l)) {
            self.on[server].insert(pos, (k, l));
            self.bump_service(k);
        }
    }

    /// Remove one (service, tier) replica from `server` (idempotent).
    /// Cloud-has-all servers hold their catalog implicitly and cannot
    /// evict per-replica; the call is a no-op there.
    pub fn evict(&mut self, server: usize, k: ServiceId, l: TierId) {
        if self.cloud_has_all[server] {
            return;
        }
        if let Ok(pos) = self.on[server].binary_search(&(k, l)) {
            self.on[server].remove(pos);
            self.bump_service(k);
        }
    }

    /// Generation of service `k`'s replica set. A rank-cache entry is
    /// valid while this matches the value it was built against; only an
    /// actual `place`/`evict` of the same service changes it.
    #[inline]
    pub fn service_gen(&self, k: ServiceId) -> u64 {
        self.service_gens.get(k.0).copied().unwrap_or(self.base_gen)
    }

    fn bump_service(&mut self, k: ServiceId) {
        if self.service_gens.len() <= k.0 {
            self.service_gens.resize(k.0 + 1, self.base_gen);
        }
        self.service_gens[k.0] = crate::model::topology::next_world_gen();
    }

    pub fn num_servers(&self) -> usize {
        self.on.len()
    }

    /// Total replicas placed on a given edge server.
    pub fn replica_count(&self, server: usize) -> usize {
        if self.cloud_has_all[server] {
            usize::MAX
        } else {
            self.on[server].len()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn catalog() -> ServiceCatalog {
        let mut rng = Rng::new(1);
        ServiceCatalog::synthetic(
            &CatalogParams { num_services: 5, num_tiers: 4, ..Default::default() },
            &mut rng,
        )
    }

    #[test]
    fn accuracy_monotone_in_tier_on_average() {
        let c = catalog();
        for k in c.services() {
            let first = c.profile(k, TierId(0)).accuracy_pct;
            let last = c.profile(k, TierId(3)).accuracy_pct;
            assert!(last > first + 20.0, "tier ladder must span accuracy band");
        }
    }

    #[test]
    fn proc_delay_monotone_in_tier() {
        let c = catalog();
        for k in c.services() {
            for ci in 0..ServerClass::COUNT {
                let p0 = c.profile(k, TierId(0)).proc_ms[ci];
                let p3 = c.profile(k, TierId(3)).proc_ms[ci];
                assert!(p3 > p0, "higher tier must be slower");
            }
        }
    }

    #[test]
    fn cloud_faster_than_edge() {
        let c = catalog();
        for k in c.services() {
            for l in c.tiers() {
                let p = c.profile(k, l);
                let cloud = p.proc_ms[ServerClass::Cloud.index()];
                for e in 0..3 {
                    assert!(cloud < p.proc_ms[e]);
                }
            }
        }
    }

    #[test]
    fn edge_band_respected_for_base_tier() {
        let c = catalog();
        for k in c.services() {
            let p = c.profile(k, TierId(0)).proc_ms[ServerClass::EdgeMedium.index()];
            assert!((950.0..=1300.0).contains(&p), "got {p}");
        }
    }

    #[test]
    fn max_accuracy_is_max() {
        let c = catalog();
        let m = c.max_accuracy_pct();
        for k in c.services() {
            for l in c.tiers() {
                assert!(c.profile(k, l).accuracy_pct <= m);
            }
        }
    }

    #[test]
    fn placement_respects_storage_and_cloud_has_all() {
        let c = catalog();
        let classes = [ServerClass::EdgeSmall, ServerClass::EdgeLarge, ServerClass::Cloud];
        let mut rng = Rng::new(2);
        let p = Placement::random(&c, &classes, &mut rng);
        assert!(p.replica_count(0) <= ServerClass::EdgeSmall.default_storage_slots());
        assert!(p.has(2, ServiceId(4), TierId(3)), "cloud must hold everything");
        // Edge replicas must be consistent with `has`.
        for (k, l) in [(ServiceId(0), TierId(0)), (ServiceId(3), TierId(2))] {
            let has = p.has(0, k, l);
            let listed = p.tiers_of(0, k, c.num_tiers).contains(&l);
            assert_eq!(has, listed);
        }
    }

    #[test]
    fn placement_full_has_everything() {
        let c = catalog();
        let p = Placement::full(&c, 2);
        for s in 0..2 {
            for k in c.services() {
                for l in c.tiers() {
                    assert!(p.has(s, k, l));
                }
            }
        }
    }

    #[test]
    fn place_and_evict_round_trip() {
        let c = catalog();
        let mut p = Placement::explicit(vec![Vec::new(), Vec::new()], vec![false, true]);
        let (k, l) = (ServiceId(2), TierId(1));
        assert!(!p.has(0, k, l));
        p.place(0, k, l);
        p.place(0, k, l); // idempotent
        assert!(p.has(0, k, l));
        assert_eq!(p.tiers_of(0, k, c.num_tiers), vec![l]);
        p.evict(0, k, l);
        p.evict(0, k, l); // idempotent
        assert!(!p.has(0, k, l));
        // Cloud-has-all servers are unaffected by per-replica mutation.
        p.evict(1, k, l);
        assert!(p.has(1, k, l));
    }

    #[test]
    fn service_generation_tracks_only_actual_mutations() {
        let mut p = Placement::explicit(vec![Vec::new(), Vec::new()], vec![false, true]);
        let (k, other) = (ServiceId(2), ServiceId(0));
        let g = p.service_gen(k);
        assert_eq!(p.service_gen(other), g, "unmutated services share base_gen");
        p.evict(0, k, TierId(1)); // absent: idempotent no-op, no bump
        assert_eq!(p.service_gen(k), g);
        p.place(0, k, TierId(1));
        let g1 = p.service_gen(k);
        assert_ne!(g1, g, "place must bump the mutated service");
        assert_eq!(p.service_gen(other), g, "other services untouched");
        p.place(0, k, TierId(1)); // duplicate: no bump
        assert_eq!(p.service_gen(k), g1);
        p.place(1, k, TierId(0)); // cloud-has-all: no-op, no bump
        assert_eq!(p.service_gen(k), g1);
        p.evict(0, k, TierId(1));
        assert_ne!(p.service_gen(k), g1, "evict must bump");
    }

    #[test]
    fn place_keeps_sorted_order_for_binary_search() {
        let mut p = Placement::explicit(vec![Vec::new()], vec![false]);
        p.place(0, ServiceId(3), TierId(0));
        p.place(0, ServiceId(1), TierId(2));
        p.place(0, ServiceId(1), TierId(0));
        for (k, l) in [(1, 0), (1, 2), (3, 0)] {
            assert!(p.has(0, ServiceId(k), TierId(l)));
        }
        assert!(!p.has(0, ServiceId(2), TierId(0)));
    }

    #[test]
    fn tiers_of_sorted_ascending_for_cloud() {
        let c = catalog();
        let p = Placement::random(&c, &[ServerClass::Cloud], &mut Rng::new(3));
        let ts = p.tiers_of(0, ServiceId(1), c.num_tiers);
        assert_eq!(ts, (0..c.num_tiers).map(TierId).collect::<Vec<_>>());
    }

    #[test]
    fn from_profiles_round_trip() {
        let c = catalog();
        let profiles: Vec<Vec<TierProfile>> = (0..c.num_services)
            .map(|k| (0..c.num_tiers).map(|l| c.profile(ServiceId(k), TierId(l)).clone()).collect())
            .collect();
        let c2 = ServiceCatalog::from_profiles(profiles);
        assert_eq!(c2.num_services, c.num_services);
        assert_eq!(c2.num_tiers, c.num_tiers);
        assert_eq!(
            c2.profile(ServiceId(2), TierId(1)).accuracy_pct,
            c.profile(ServiceId(2), TierId(1)).accuracy_pct
        );
    }
}
