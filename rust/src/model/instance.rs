//! A complete MUS problem instance: topology + catalog + placement +
//! requests + the normalization constants (Max_as, Max_cs) of Def. II.1.
//!
//! `candidates_into(i, buf)` enumerates every feasible-by-placement
//! (server, tier) option for request i with its completion time
//! `c_ijkl = T^comm (if offloaded) + T^q + T^proc` — Eq. (II) of the
//! paper — leaving QoS/capacity filtering to the schedulers (the Happy-*
//! baselines relax different constraints).
//!
//! The world (topology/catalog/placement) is held behind [`Cow`]: batch
//! callers own it (`ProblemInstance::new`), while the DES decision loop
//! borrows the live world every frame (`ProblemInstance::borrowed`) and
//! attaches the per-frame residual γ as a side slice — no per-frame
//! deep clones. Schedulers must therefore read capacities through
//! [`ProblemInstance::gamma`]/[`ProblemInstance::eta`], never from the
//! topology's servers directly.

use std::borrow::Cow;

use crate::model::request::Request;
use crate::model::server::ServerId;
use crate::model::service::{Placement, ServiceCatalog, TierId};
use crate::model::topology::Topology;

/// One scheduling option for a request: serve on `server` with model tier
/// `tier` of the requested service.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Candidate {
    pub server: ServerId,
    pub tier: TierId,
    /// Provided accuracy a_ijkl (percent).
    pub accuracy_pct: f64,
    /// Completion time c_ijkl (ms), including T^q and T^comm if offloaded.
    pub completion_ms: f64,
    /// Computation cost v_ijkl (γ units at `server`).
    pub comp_cost: f64,
    /// Communication cost u_ijkl (η units at the covering server; only
    /// charged when `offloaded`).
    pub comm_cost: f64,
    /// True iff `server != s_i`.
    pub offloaded: bool,
}

/// The full instance handed to schedulers.
///
/// `'w` is the lifetime of the borrowed world; owned instances (the
/// common case outside the DES) are `ProblemInstance<'static>`.
#[derive(Clone, Debug)]
pub struct ProblemInstance<'w> {
    pub topology: Cow<'w, Topology>,
    pub catalog: Cow<'w, ServiceCatalog>,
    pub placement: Cow<'w, Placement>,
    pub requests: Vec<Request>,
    /// Max possible accuracy in the system (Def. II.1 `Max_as`, percent).
    pub max_accuracy_pct: f64,
    /// Worst-case completion time (Def. II.1 `Max_cs`, ms).
    pub max_completion_ms: f64,
    /// Per-frame residual computation capacity, indexed by server. When
    /// present it overrides `topology.servers[j].gamma` (read through
    /// [`ProblemInstance::gamma`]); the DES attaches it instead of
    /// cloning the topology and mutating γ in place.
    residual_gamma: Option<Vec<f64>>,
}

impl ProblemInstance<'static> {
    pub fn new(
        topology: Topology,
        catalog: ServiceCatalog,
        placement: Placement,
        requests: Vec<Request>,
    ) -> ProblemInstance<'static> {
        ProblemInstance::from_parts(
            Cow::Owned(topology),
            Cow::Owned(catalog),
            Cow::Owned(placement),
            requests,
        )
    }
}

impl<'w> ProblemInstance<'w> {
    /// General constructor: any mix of borrowed and owned world parts.
    pub fn from_parts(
        topology: Cow<'w, Topology>,
        catalog: Cow<'w, ServiceCatalog>,
        placement: Cow<'w, Placement>,
        requests: Vec<Request>,
    ) -> ProblemInstance<'w> {
        assert_eq!(
            placement.num_servers(),
            topology.len(),
            "placement must cover every server"
        );
        let max_accuracy_pct = catalog.max_accuracy_pct();
        // Paper §IV fixes Max_cs = 12000 ms; keep that as the default and
        // let callers override via `with_normalization`.
        let max_completion_ms = 12_000.0;
        ProblemInstance {
            topology,
            catalog,
            placement,
            requests,
            max_accuracy_pct,
            max_completion_ms,
            residual_gamma: None,
        }
    }

    /// Zero-copy constructor: borrow the live world (DES / serving hot
    /// paths).
    pub fn borrowed(
        topology: &'w Topology,
        catalog: &'w ServiceCatalog,
        placement: &'w Placement,
        requests: Vec<Request>,
    ) -> ProblemInstance<'w> {
        ProblemInstance::from_parts(
            Cow::Borrowed(topology),
            Cow::Borrowed(catalog),
            Cow::Borrowed(placement),
            requests,
        )
    }

    /// Attach the per-frame residual γ slice (one entry per server).
    pub fn with_residual_gamma(mut self, residual_gamma: Vec<f64>) -> Self {
        assert_eq!(residual_gamma.len(), self.topology.len());
        self.residual_gamma = Some(residual_gamma);
        self
    }

    // lint:no-alloc:begin — capacity accessors sit inside every
    // scheduler's inner loop.
    /// Effective computation capacity γ_j for this instance: the
    /// per-frame residual when one is attached, else the topology's
    /// steady-state value.
    #[inline]
    pub fn gamma(&self, j: usize) -> f64 {
        match &self.residual_gamma {
            Some(r) => r[j],
            None => self.topology.servers[j].gamma,
        }
    }

    /// Communication capacity η_j (never overridden per frame: offload
    /// slots free up at the frame boundary).
    #[inline]
    pub fn eta(&self, j: usize) -> f64 {
        self.topology.servers[j].eta
    }
    // lint:no-alloc:end

    /// Tear down the instance and hand its owned buffers back to the
    /// caller, so a pooled hot path (DES `FrameScratch`) can reuse their
    /// capacity on the next frame.
    pub fn into_buffers(self) -> (Vec<Request>, Option<Vec<f64>>) {
        (self.requests, self.residual_gamma)
    }

    pub fn with_normalization(mut self, max_accuracy_pct: f64, max_completion_ms: f64) -> Self {
        assert!(max_accuracy_pct > 0.0 && max_completion_ms > 0.0);
        self.max_accuracy_pct = max_accuracy_pct;
        self.max_completion_ms = max_completion_ms;
        self
    }

    pub fn num_requests(&self) -> usize {
        self.requests.len()
    }

    pub fn num_servers(&self) -> usize {
        self.topology.len()
    }

    /// The completion time of serving request `i` at server `j` with tier
    /// `l`: offloaded requests pay the covering-edge→j forwarding delay.
    pub fn completion_ms(&self, req: &Request, server: ServerId, tier: TierId) -> f64 {
        let profile = self.catalog.profile(req.service, tier);
        let proc = profile.proc_ms[self.topology.server(server).class.index()];
        let comm = if server == req.covering {
            0.0
        } else {
            self.topology.comm_ms(req.covering, server)
        };
        req.queue_delay_ms + comm + proc
    }

    /// Enumerate all placement-feasible candidates for request `i` into
    /// `out` (cleared first). No QoS or capacity filtering here
    /// (schedulers differ on that) — but down servers (scenario outages)
    /// are excluded outright: every policy, including the Happy-*
    /// relaxations, must respect them.
    ///
    /// The buffer form is the hot-path API: schedulers reuse one
    /// `Vec<Candidate>` across every request of every frame, so the
    /// steady-state enumeration cost is pure writes into warm capacity.
    // lint:no-alloc:begin — candidate enumeration writes into warm
    // caller-owned capacity only (`for_each_tier` replaces the old
    // per-call `tiers_of` Vec).
    pub fn candidates_into(&self, i: usize, out: &mut Vec<Candidate>) {
        out.clear();
        let req = &self.requests[i];
        for j in 0..self.topology.len() {
            if !self.topology.servers[j].up {
                continue;
            }
            let server = ServerId(j);
            self.placement
                .for_each_tier(j, req.service, self.catalog.num_tiers, |tier| {
                    let profile = self.catalog.profile(req.service, tier);
                    out.push(Candidate {
                        server,
                        tier,
                        accuracy_pct: profile.accuracy_pct,
                        completion_ms: self.completion_ms(req, server, tier),
                        comp_cost: profile.comp_cost,
                        comm_cost: profile.comm_cost,
                        offloaded: server != req.covering,
                    });
                });
        }
    }
    // lint:no-alloc:end

    /// Allocating convenience wrapper around [`Self::candidates_into`].
    pub fn candidates(&self, i: usize) -> Vec<Candidate> {
        let mut out = Vec::new();
        self.candidates_into(i, &mut out);
        out
    }

    /// Sanity-check internal consistency; used by config loading and
    /// property tests.
    pub fn validate(&self) -> Result<(), String> {
        for req in &self.requests {
            if req.covering.0 >= self.topology.len() {
                return Err(format!("request {:?} covered by unknown server", req.id));
            }
            if self.topology.server(req.covering).is_cloud() {
                return Err(format!(
                    "request {:?} covered by the cloud — users cannot reach the cloud directly",
                    req.id
                ));
            }
            if req.service.0 >= self.catalog.num_services {
                return Err(format!("request {:?} asks for unknown service", req.id));
            }
            if !(0.0..=100.0).contains(&req.min_accuracy_pct) {
                return Err(format!("request {:?} has invalid A_i", req.id));
            }
            if req.max_completion_ms < 0.0 {
                return Err(format!("request {:?} has negative C_i", req.id));
            }
        }
        if self.max_accuracy_pct <= 0.0 || self.max_completion_ms <= 0.0 {
            return Err("non-positive normalization constants".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::server::{Server, ServerClass};
    use crate::model::service::{CatalogParams, ServiceId};
    use crate::model::topology::TopologyParams;
    use crate::util::rng::Rng;

    pub fn tiny_instance() -> ProblemInstance<'static> {
        let mut rng = Rng::new(42);
        let topology = Topology::paper_default(
            &TopologyParams { num_edge: 3, num_cloud: 1, ..Default::default() },
            &mut rng,
        );
        let catalog = ServiceCatalog::synthetic(
            &CatalogParams { num_services: 4, num_tiers: 3, ..Default::default() },
            &mut rng,
        );
        let placement = Placement::full(&catalog, 3).into_with_cloud();
        let requests = vec![
            Request::new(0, 0, 0).with_queue_delay(10.0),
            Request::new(1, 1, 1),
            Request::new(2, 2, 2).with_qos(80.0, 900.0),
        ];
        ProblemInstance::new(topology, catalog, placement, requests)
    }

    // Helper: extend a 3-edge `full` placement with a cloud row.
    trait WithCloud {
        fn into_with_cloud(self) -> Placement;
    }
    impl WithCloud for Placement {
        fn into_with_cloud(self) -> Placement {
            // Rebuild: 3 edges full + cloud-has-all.
            let mut on = Vec::new();
            let mut cloud = Vec::new();
            for s in 0..3 {
                let mut pairs = Vec::new();
                for k in 0..4 {
                    for l in 0..3 {
                        if self.has(s, ServiceId(k), TierId(l)) {
                            pairs.push((ServiceId(k), TierId(l)));
                        }
                    }
                }
                on.push(pairs);
                cloud.push(false);
            }
            on.push(Vec::new());
            cloud.push(true);
            Placement::explicit(on, cloud)
        }
    }

    #[test]
    fn candidates_cover_all_servers_with_full_placement() {
        let inst = tiny_instance();
        let cands = inst.candidates(0);
        // 4 servers × 3 tiers.
        assert_eq!(cands.len(), 12);
        assert!(cands.iter().any(|c| c.server == ServerId(3)), "cloud candidate present");
    }

    #[test]
    fn candidates_skip_down_servers() {
        let mut inst = tiny_instance();
        inst.topology.to_mut().servers[1].up = false;
        let cands = inst.candidates(0);
        assert_eq!(cands.len(), 9, "3 live servers × 3 tiers");
        assert!(cands.iter().all(|c| c.server != ServerId(1)));
    }

    #[test]
    fn local_candidate_has_no_comm_delay() {
        let inst = tiny_instance();
        let req = &inst.requests[0];
        for c in inst.candidates(0) {
            let profile = inst.catalog.profile(req.service, c.tier);
            let proc = profile.proc_ms[inst.topology.server(c.server).class.index()];
            if !c.offloaded {
                assert!((c.completion_ms - (req.queue_delay_ms + proc)).abs() < 1e-9);
            } else {
                assert!(c.completion_ms > req.queue_delay_ms + proc);
            }
        }
    }

    #[test]
    fn queue_delay_included() {
        let inst = tiny_instance();
        let base = inst.completion_ms(&inst.requests[0], ServerId(0), TierId(0));
        let mut req2 = inst.requests[0].clone();
        req2.queue_delay_ms += 100.0;
        let with_queue = inst.completion_ms(&req2, ServerId(0), TierId(0));
        assert!((with_queue - base - 100.0).abs() < 1e-9);
    }

    #[test]
    fn validate_accepts_good_instance() {
        assert!(tiny_instance().validate().is_ok());
    }

    #[test]
    fn validate_rejects_cloud_covering() {
        let mut inst = tiny_instance();
        inst.requests[0].covering = ServerId(3); // the cloud
        assert!(inst.validate().is_err());
    }

    #[test]
    fn validate_rejects_unknown_service() {
        let mut inst = tiny_instance();
        inst.requests[0].service = ServiceId(99);
        assert!(inst.validate().is_err());
    }

    #[test]
    fn residual_gamma_overrides_topology() {
        let inst = tiny_instance();
        let n = inst.num_servers();
        for j in 0..n {
            assert_eq!(inst.gamma(j), inst.topology.servers[j].gamma);
            assert_eq!(inst.eta(j), inst.topology.servers[j].eta);
        }
        let inst = inst.with_residual_gamma(vec![0.5; n]);
        for j in 0..n {
            assert_eq!(inst.gamma(j), 0.5);
        }
        let (requests, residual) = inst.into_buffers();
        assert_eq!(requests.len(), 3);
        assert_eq!(residual.unwrap(), vec![0.5; n]);
    }

    #[test]
    fn borrowed_instance_enumerates_like_owned() {
        let owned = tiny_instance();
        let borrowed = ProblemInstance::borrowed(
            &owned.topology,
            &owned.catalog,
            &owned.placement,
            owned.requests.clone(),
        );
        let mut buf = Vec::new();
        for i in 0..owned.num_requests() {
            borrowed.candidates_into(i, &mut buf);
            assert_eq!(buf, owned.candidates(i));
        }
    }

    #[test]
    fn cloud_candidates_offloaded_and_fast() {
        let inst = tiny_instance();
        let cands = inst.candidates(1);
        let cloud: Vec<_> = cands.iter().filter(|c| c.server == ServerId(3)).collect();
        assert!(!cloud.is_empty());
        for c in cloud {
            assert!(c.offloaded);
            // Cloud proc ≈ 300·slowdown, edge ≥ 950: cloud candidates beat
            // local ones on processing even after the comm delay.
            let local_same_tier = cands
                .iter()
                .find(|o| !o.offloaded && o.tier == c.tier)
                .unwrap();
            assert!(c.completion_ms < local_same_tier.completion_ms);
        }
    }
}
