//! The three-tier topology: users → covering edge servers → peer edges and
//! the cloud. Users never talk to the cloud directly (paper §II); all
//! offloads originate at the covering edge server.
//!
//! Communication delays are held as a per-pair matrix (ms per request
//! payload), calibrated from the paper's testbed numbers by default and
//! recomputable from a `net::LinkModel` on the serving path.

use crate::model::server::{Server, ServerClass, ServerId};
use crate::util::rng::Rng;
use std::sync::atomic::{AtomicU64, Ordering};

/// Process-global generation source for world mutations. Every draw is
/// unique, so a freshly constructed `Topology`/`Placement` can never
/// collide with a stale cache entry stamped from a previous world — the
/// serving leader loop rebuilds its topology every frame and relies on
/// exactly this property.
static WORLD_GEN: AtomicU64 = AtomicU64::new(1);

/// Draw the next globally unique world generation.
pub fn next_world_gen() -> u64 {
    WORLD_GEN.fetch_add(1, Ordering::Relaxed)
}

/// The server graph.
#[derive(Clone, Debug)]
pub struct Topology {
    pub servers: Vec<Server>,
    /// Row-major `n×n` delay matrix: entry `a·n + b` is the delay to
    /// forward one request payload a→b. Flattened to a single allocation
    /// so the DES hot path gets one contiguous, cache-friendly block
    /// instead of a pointer-chased `Vec<Vec<f64>>`.
    comm_ms: Box<[f64]>,
    /// Bumped whenever a server's `up` flag changes through
    /// [`Topology::set_up`]. Consumed by the coordinator rank cache.
    up_gen: u64,
    /// Per-source-row comm generation: `comm_row_gen[a]` is bumped when
    /// any outgoing delay of server `a` changes. US scores only ever read
    /// `comm_ms(covering, ·)`, so a rank class keyed on its covering
    /// server survives drifts on unrelated rows.
    comm_row_gen: Vec<u64>,
}

/// Parameters for the default paper-style topology.
#[derive(Clone, Debug)]
pub struct TopologyParams {
    pub num_edge: usize,
    pub num_cloud: usize,
    /// Mean edge↔edge forwarding delay (ms per payload); testbed-derived.
    pub edge_edge_ms: f64,
    /// Mean edge↔cloud forwarding delay (ms per payload); the testbed path
    /// traverses the RP3 forwarder, so this is larger.
    pub edge_cloud_ms: f64,
    /// Multiplicative jitter half-range applied per pair (0.0 = none).
    pub jitter: f64,
}

impl Default for TopologyParams {
    fn default() -> Self {
        // Paper §IV numerics: 9 edge + 1 cloud; B ≈ 600 bytes/ms and
        // ~14 kB images give ~23 ms per image edge↔edge; the edge↔cloud
        // path adds the forwarder hop.
        TopologyParams {
            num_edge: 9,
            num_cloud: 1,
            edge_edge_ms: 25.0,
            edge_cloud_ms: 60.0,
            jitter: 0.2,
        }
    }
}

impl Topology {
    /// Build the paper's topology: `num_edge` edge servers cycling through
    /// the three heterogeneity classes, plus `num_cloud` cloud servers.
    pub fn paper_default(params: &TopologyParams, rng: &mut Rng) -> Topology {
        assert!(params.num_edge > 0);
        let mut servers = Vec::with_capacity(params.num_edge + params.num_cloud);
        for i in 0..params.num_edge {
            let class = ServerClass::EDGE_CLASSES[i % 3];
            servers.push(Server::new(i, class));
        }
        for i in 0..params.num_cloud {
            servers.push(Server::new(params.num_edge + i, ServerClass::Cloud));
        }
        let n = servers.len();
        // Row-major fill in the same a-outer/b-inner order (skipping the
        // diagonal) as the historical nested-Vec build, so the RNG draw
        // sequence — and therefore every seeded experiment — is unchanged.
        let mut comm_ms = vec![0.0; n * n];
        for a in 0..n {
            for b in 0..n {
                if a == b {
                    continue;
                }
                let base = if servers[a].is_cloud() || servers[b].is_cloud() {
                    params.edge_cloud_ms
                } else {
                    params.edge_edge_ms
                };
                comm_ms[a * n + b] = base * rng.uniform(1.0 - params.jitter, 1.0 + params.jitter);
            }
        }
        let gen = next_world_gen();
        Topology {
            servers,
            comm_ms: comm_ms.into_boxed_slice(),
            up_gen: gen,
            comm_row_gen: vec![gen; n],
        }
    }

    /// Explicit construction (tests, serving path).
    pub fn explicit(servers: Vec<Server>, comm_ms: Vec<Vec<f64>>) -> Topology {
        let n = servers.len();
        assert_eq!(comm_ms.len(), n);
        assert!(comm_ms.iter().all(|row| row.len() == n));
        let flat: Vec<f64> = comm_ms.into_iter().flatten().collect();
        let gen = next_world_gen();
        Topology {
            servers,
            comm_ms: flat.into_boxed_slice(),
            up_gen: gen,
            comm_row_gen: vec![gen; n],
        }
    }

    pub fn len(&self) -> usize {
        self.servers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.servers.is_empty()
    }

    pub fn server(&self, id: ServerId) -> &Server {
        &self.servers[id.0]
    }

    /// Communication delay T^comm for forwarding one request a→b (ms).
    #[inline]
    pub fn comm_ms(&self, a: ServerId, b: ServerId) -> f64 {
        self.comm_ms[a.0 * self.servers.len() + b.0]
    }

    /// Overwrite one directed link delay (used by the serving path when
    /// the bandwidth estimator updates its expectation, and by scenario
    /// `BandwidthDrift` events). Bumps the source row's generation so
    /// rank-cache classes covering server `a` rebuild lazily.
    pub fn set_comm_ms(&mut self, a: ServerId, b: ServerId, ms: f64) {
        self.comm_ms[a.0 * self.servers.len() + b.0] = ms;
        self.comm_row_gen[a.0] = next_world_gen();
    }

    /// Flip a server's availability flag; bumps the up-generation only
    /// on an actual change (a `ServerDown` on an already-down server must
    /// not thrash the rank cache). All scenario/serving outage mutations
    /// route through here so cache invalidation cannot be bypassed.
    pub fn set_up(&mut self, server: ServerId, up: bool) {
        if self.servers[server.0].up != up {
            self.servers[server.0].up = up;
            self.up_gen = next_world_gen();
        }
    }

    /// Generation of the up/down availability state.
    #[inline]
    pub fn up_gen(&self) -> u64 {
        self.up_gen
    }

    /// Generation of the outgoing comm row of server `a`.
    #[inline]
    pub fn comm_row_gen(&self, a: ServerId) -> u64 {
        self.comm_row_gen[a.0]
    }

    /// Snapshot of the full comm matrix (as nested rows, for callers that
    /// want the historical shape). The scenario engine keeps this as the
    /// baseline that `BandwidthDrift` events scale against, so a drift
    /// back to factor 1.0 restores the exact original delays.
    pub fn comm_matrix(&self) -> Vec<Vec<f64>> {
        let n = self.servers.len();
        if n == 0 {
            return Vec::new();
        }
        self.comm_ms.chunks(n).map(|row| row.to_vec()).collect()
    }

    pub fn edge_ids(&self) -> Vec<ServerId> {
        self.servers.iter().filter(|s| !s.is_cloud()).map(|s| s.id).collect()
    }

    pub fn cloud_ids(&self) -> Vec<ServerId> {
        self.servers.iter().filter(|s| s.is_cloud()).map(|s| s.id).collect()
    }

    /// Worst-case completion time `Max_cs` ingredient: the largest
    /// pairwise communication delay in the system.
    pub fn max_comm_ms(&self) -> f64 {
        self.comm_ms.iter().copied().fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn topo() -> Topology {
        Topology::paper_default(&TopologyParams::default(), &mut Rng::new(1))
    }

    #[test]
    fn paper_default_has_nine_edges_one_cloud() {
        let t = topo();
        assert_eq!(t.len(), 10);
        assert_eq!(t.edge_ids().len(), 9);
        assert_eq!(t.cloud_ids(), vec![ServerId(9)]);
    }

    #[test]
    fn self_delay_zero_others_positive() {
        let t = topo();
        for a in 0..t.len() {
            for b in 0..t.len() {
                let d = t.comm_ms(ServerId(a), ServerId(b));
                if a == b {
                    assert_eq!(d, 0.0);
                } else {
                    assert!(d > 0.0);
                }
            }
        }
    }

    #[test]
    fn cloud_links_slower_than_edge_links_on_average() {
        let t = topo();
        let cloud = t.cloud_ids()[0];
        let edges = t.edge_ids();
        let avg_cloud: f64 = edges.iter().map(|e| t.comm_ms(*e, cloud)).sum::<f64>()
            / edges.len() as f64;
        let mut edge_sum = 0.0;
        let mut n = 0;
        for &a in &edges {
            for &b in &edges {
                if a != b {
                    edge_sum += t.comm_ms(a, b);
                    n += 1;
                }
            }
        }
        assert!(avg_cloud > edge_sum / n as f64);
    }

    #[test]
    fn heterogeneity_classes_cycle() {
        let t = topo();
        assert_eq!(t.server(ServerId(0)).class, ServerClass::EdgeSmall);
        assert_eq!(t.server(ServerId(1)).class, ServerClass::EdgeMedium);
        assert_eq!(t.server(ServerId(2)).class, ServerClass::EdgeLarge);
        assert_eq!(t.server(ServerId(3)).class, ServerClass::EdgeSmall);
    }

    #[test]
    fn set_comm_ms_updates() {
        let mut t = topo();
        t.set_comm_ms(ServerId(0), ServerId(1), 99.0);
        assert_eq!(t.comm_ms(ServerId(0), ServerId(1)), 99.0);
    }

    #[test]
    fn set_comm_ms_bumps_only_the_source_row_generation() {
        let mut t = topo();
        let g0 = t.comm_row_gen(ServerId(0));
        let g1 = t.comm_row_gen(ServerId(1));
        t.set_comm_ms(ServerId(0), ServerId(1), 99.0);
        assert_ne!(t.comm_row_gen(ServerId(0)), g0, "source row must be bumped");
        assert_eq!(t.comm_row_gen(ServerId(1)), g1, "other rows must be untouched");
    }

    #[test]
    fn set_up_bumps_generation_only_on_actual_change() {
        let mut t = topo();
        let g0 = t.up_gen();
        t.set_up(ServerId(0), true); // already up: no-op
        assert_eq!(t.up_gen(), g0);
        t.set_up(ServerId(0), false);
        let g1 = t.up_gen();
        assert_ne!(g1, g0);
        assert!(!t.server(ServerId(0)).up);
        t.set_up(ServerId(0), false); // already down: no-op
        assert_eq!(t.up_gen(), g1);
        t.set_up(ServerId(0), true);
        assert_ne!(t.up_gen(), g1);
    }

    #[test]
    fn fresh_topologies_never_share_generations() {
        let a = topo();
        let b = topo();
        assert_ne!(a.up_gen(), b.up_gen());
        assert_ne!(a.comm_row_gen(ServerId(0)), b.comm_row_gen(ServerId(0)));
    }

    #[test]
    fn comm_matrix_snapshot_is_decoupled() {
        let mut t = topo();
        let snap = t.comm_matrix();
        t.set_comm_ms(ServerId(0), ServerId(1), 99.0);
        assert_ne!(snap[0][1], 99.0, "snapshot must not alias the live matrix");
        assert_eq!(snap[0][2], t.comm_ms(ServerId(0), ServerId(2)));
    }

    #[test]
    fn max_comm_is_max() {
        let t = topo();
        let m = t.max_comm_ms();
        for a in 0..t.len() {
            for b in 0..t.len() {
                assert!(t.comm_ms(ServerId(a), ServerId(b)) <= m);
            }
        }
    }

    #[test]
    fn deterministic_in_seed() {
        let a = Topology::paper_default(&TopologyParams::default(), &mut Rng::new(5));
        let b = Topology::paper_default(&TopologyParams::default(), &mut Rng::new(5));
        assert_eq!(a.comm_ms(ServerId(0), ServerId(3)), b.comm_ms(ServerId(0), ServerId(3)));
    }
}
