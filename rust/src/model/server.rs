//! Servers: the M set. Edge servers come in three heterogeneity classes
//! (paper §IV: "three types of edge servers ... differ based on their
//! storage, communication, and computation capacities"); the cloud is
//! modelled identically but with larger capacities and no coverage.

/// Index into `Topology::servers`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ServerId(pub usize);

impl std::fmt::Display for ServerId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "s{}", self.0)
    }
}

/// Heterogeneity class of a server.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ServerClass {
    /// Raspberry-Pi-class small edge node.
    EdgeSmall,
    /// Mid-range edge node.
    EdgeMedium,
    /// Well-provisioned edge node.
    EdgeLarge,
    /// The (resource-constrained) cloud tier.
    Cloud,
}

impl ServerClass {
    pub fn is_cloud(self) -> bool {
        matches!(self, ServerClass::Cloud)
    }

    /// All edge classes in ascending capability order.
    pub const EDGE_CLASSES: [ServerClass; 3] =
        [ServerClass::EdgeSmall, ServerClass::EdgeMedium, ServerClass::EdgeLarge];

    /// Index used by the catalog's per-class processing-delay tables.
    pub fn index(self) -> usize {
        match self {
            ServerClass::EdgeSmall => 0,
            ServerClass::EdgeMedium => 1,
            ServerClass::EdgeLarge => 2,
            ServerClass::Cloud => 3,
        }
    }

    pub const COUNT: usize = 4;

    /// Default computation capacity γ (abstract units ≈ concurrent
    /// inference slots per decision frame; paper testbed: 3 threads).
    pub fn default_gamma(self) -> f64 {
        match self {
            ServerClass::EdgeSmall => 2.0,
            ServerClass::EdgeMedium => 3.0,
            ServerClass::EdgeLarge => 4.0,
            ServerClass::Cloud => 24.0,
        }
    }

    /// Default communication capacity η (images forwardable per frame;
    /// paper testbed: 10).
    pub fn default_eta(self) -> f64 {
        match self {
            ServerClass::EdgeSmall => 6.0,
            ServerClass::EdgeMedium => 10.0,
            ServerClass::EdgeLarge => 14.0,
            ServerClass::Cloud => 48.0,
        }
    }

    /// Default storage capacity: how many (service, tier) replicas fit.
    pub fn default_storage_slots(self) -> usize {
        match self {
            ServerClass::EdgeSmall => 40,
            ServerClass::EdgeMedium => 80,
            ServerClass::EdgeLarge => 140,
            ServerClass::Cloud => usize::MAX,
        }
    }
}

/// One server in the M set.
#[derive(Clone, Debug)]
pub struct Server {
    pub id: ServerId,
    pub class: ServerClass,
    /// Computation capacity γ_j (constraint 2d).
    pub gamma: f64,
    /// Communication capacity η_j (constraint 2e).
    pub eta: f64,
    /// Availability: the scenario engine flips this on `ServerDown`/
    /// `ServerUp` events. A down server is not a candidate target and its
    /// γ/η budgets are unusable (its coverage still exists — queued
    /// requests covered by it drain as drops).
    pub up: bool,
}

impl Server {
    pub fn new(id: usize, class: ServerClass) -> Server {
        Server {
            id: ServerId(id),
            class,
            gamma: class.default_gamma(),
            eta: class.default_eta(),
            up: true,
        }
    }

    pub fn with_capacities(mut self, gamma: f64, eta: f64) -> Server {
        self.gamma = gamma;
        self.eta = eta;
        self
    }

    pub fn with_up(mut self, up: bool) -> Server {
        self.up = up;
        self
    }

    pub fn is_cloud(&self) -> bool {
        self.class.is_cloud()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn class_indices_are_dense() {
        let mut seen = [false; ServerClass::COUNT];
        for c in [
            ServerClass::EdgeSmall,
            ServerClass::EdgeMedium,
            ServerClass::EdgeLarge,
            ServerClass::Cloud,
        ] {
            seen[c.index()] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn cloud_dominates_edges_in_capacity() {
        for c in ServerClass::EDGE_CLASSES {
            assert!(ServerClass::Cloud.default_gamma() > c.default_gamma());
            assert!(ServerClass::Cloud.default_eta() > c.default_eta());
        }
    }

    #[test]
    fn edge_classes_strictly_ordered() {
        let g: Vec<f64> = ServerClass::EDGE_CLASSES.iter().map(|c| c.default_gamma()).collect();
        assert!(g[0] < g[1] && g[1] < g[2]);
    }

    #[test]
    fn builder_overrides() {
        let s = Server::new(3, ServerClass::EdgeSmall).with_capacities(7.0, 9.0);
        assert_eq!(s.gamma, 7.0);
        assert_eq!(s.eta, 9.0);
        assert_eq!(s.id, ServerId(3));
        assert!(!s.is_cloud());
    }

    #[test]
    fn servers_start_up_and_can_be_downed() {
        let s = Server::new(0, ServerClass::EdgeMedium);
        assert!(s.up, "servers must default to available");
        let s = s.with_up(false);
        assert!(!s.up);
        assert!(s.with_up(true).up);
    }
}
