//! Problem domain: the three-tier user–edge–cloud system of the MUS paper.
//!
//! * [`server`] — heterogeneous edge/cloud servers with computation (γ) and
//!   communication (η) capacities;
//! * [`service`] — the service catalog: |K| services × |L| DL-model tiers
//!   with (accuracy, processing-delay, cost) profiles, plus the placement
//!   of model replicas on servers;
//! * [`request`] — user requests with QoS thresholds (A_i, C_i) and
//!   satisfaction weights (w_a, w_c);
//! * [`topology`] — the server graph and per-hop communication delays;
//! * [`instance`] — a complete [`instance::ProblemInstance`] handed to the
//!   schedulers, with candidate enumeration.

pub mod instance;
pub mod request;
pub mod server;
pub mod service;
pub mod topology;

pub use instance::{Candidate, ProblemInstance};
pub use request::Request;
pub use server::{Server, ServerClass, ServerId};
pub use service::{Placement, ServiceCatalog, ServiceId, TierId};
pub use topology::Topology;
