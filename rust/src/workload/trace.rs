//! Request traces: record / replay workloads as JSON so experiments are
//! exactly repeatable across machines and so external traces (e.g. from
//! a production edge deployment) can drive the simulators.

use crate::model::request::Request;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::workload::WorkloadParams;
use anyhow::{Context, Result};

/// One timestamped request record.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceRecord {
    pub arrival_ms: f64,
    pub service: usize,
    pub covering_edge: usize,
    pub min_accuracy_pct: f64,
    pub max_completion_ms: f64,
    pub payload_bytes: u64,
    pub priority: u8,
}

/// An ordered workload trace.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Trace {
    pub records: Vec<TraceRecord>,
}

impl Trace {
    /// Synthesize a Poisson trace from the §IV distributions.
    pub fn synthesize(
        params: &WorkloadParams,
        num_services: usize,
        num_edges: usize,
        horizon_ms: f64,
        rate_per_s: f64,
        rng: &mut Rng,
    ) -> Trace {
        assert!(num_edges > 0 && num_services > 0 && rate_per_s > 0.0);
        let gap = 1000.0 / rate_per_s;
        let mut t = rng.uniform(0.0, gap);
        let mut records = Vec::new();
        while t <= horizon_ms {
            records.push(TraceRecord {
                arrival_ms: t,
                service: rng.index(num_services),
                covering_edge: rng.index(num_edges),
                min_accuracy_pct: rng.normal_clamped(
                    params.accuracy_mean_pct,
                    params.accuracy_std_pct,
                    0.0,
                    100.0,
                ),
                max_completion_ms: rng.normal_clamped(
                    params.deadline_mean_ms,
                    params.deadline_std_ms,
                    0.0,
                    params.max_completion_ms,
                ),
                payload_bytes: rng.u64_range(params.payload_lo_bytes, params.payload_hi_bytes),
                priority: 0,
            });
            t -= gap * (1.0 - rng.f64()).ln();
        }
        Trace { records }
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Convert the records arriving in `[from_ms, to_ms)` into scheduler
    /// requests, with T^q measured against the decision time `to_ms`.
    pub fn window_requests(&self, from_ms: f64, to_ms: f64, edge_server_ids: &[usize]) -> Vec<Request> {
        self.records
            .iter()
            .filter(|r| r.arrival_ms >= from_ms && r.arrival_ms < to_ms)
            .enumerate()
            .map(|(i, r)| {
                Request::new(i, r.service, edge_server_ids[r.covering_edge % edge_server_ids.len()])
                    .with_qos(r.min_accuracy_pct, r.max_completion_ms)
                    .with_queue_delay((to_ms - r.arrival_ms).max(0.0))
                    .with_payload(r.payload_bytes)
                    .with_priority(r.priority)
            })
            .collect()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![(
            "records",
            Json::arr(self.records.iter().map(|r| {
                Json::obj(vec![
                    ("arrival_ms", Json::num(r.arrival_ms)),
                    ("service", Json::num(r.service as f64)),
                    ("covering_edge", Json::num(r.covering_edge as f64)),
                    ("min_accuracy_pct", Json::num(r.min_accuracy_pct)),
                    ("max_completion_ms", Json::num(r.max_completion_ms)),
                    ("payload_bytes", Json::num(r.payload_bytes as f64)),
                    ("priority", Json::num(r.priority as f64)),
                ])
            })),
        )])
    }

    pub fn from_json(j: &Json) -> Result<Trace> {
        let mut records = Vec::new();
        for r in j.get("records").as_arr().context("trace: records[]")? {
            records.push(TraceRecord {
                arrival_ms: r.get("arrival_ms").as_f64().context("arrival_ms")?,
                service: r.get("service").as_usize().context("service")?,
                covering_edge: r.get("covering_edge").as_usize().context("covering_edge")?,
                min_accuracy_pct: r.get("min_accuracy_pct").as_f64().context("min_accuracy")?,
                max_completion_ms: r.get("max_completion_ms").as_f64().context("max_completion")?,
                payload_bytes: r.get("payload_bytes").as_usize().context("payload")? as u64,
                priority: r.get("priority").as_usize().unwrap_or(0) as u8,
            });
        }
        Ok(Trace { records })
    }

    pub fn save(&self, path: &str) -> Result<()> {
        std::fs::write(path, self.to_json().pretty()).with_context(|| format!("writing {path}"))
    }

    pub fn load(path: &str) -> Result<Trace> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        Trace::from_json(&Json::parse(&text).context("parsing trace")?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Trace {
        let mut rng = Rng::new(3);
        Trace::synthesize(&WorkloadParams::default(), 10, 4, 30_000.0, 2.0, &mut rng)
    }

    #[test]
    fn synthesize_is_ordered_and_plausible() {
        let t = sample();
        assert!(t.len() > 30, "expect ~60 records, got {}", t.len());
        for w in t.records.windows(2) {
            assert!(w[0].arrival_ms <= w[1].arrival_ms);
        }
        for r in &t.records {
            assert!(r.service < 10 && r.covering_edge < 4);
            assert!((0.0..=100.0).contains(&r.min_accuracy_pct));
        }
    }

    #[test]
    fn json_round_trip_exact() {
        let t = sample();
        let t2 = Trace::from_json(&Json::parse(&t.to_json().pretty()).unwrap()).unwrap();
        assert_eq!(t.len(), t2.len());
        assert_eq!(t.records[5].service, t2.records[5].service);
        assert!((t.records[5].arrival_ms - t2.records[5].arrival_ms).abs() < 1e-9);
    }

    #[test]
    fn save_load_round_trip() {
        let dir = std::env::temp_dir().join("edgeus_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.json").to_string_lossy().to_string();
        let t = sample();
        t.save(&path).unwrap();
        let t2 = Trace::load(&path).unwrap();
        assert_eq!(t.len(), t2.len());
    }

    #[test]
    fn window_requests_computes_tq() {
        let t = sample();
        let reqs = t.window_requests(0.0, 3000.0, &[0, 1, 2, 3]);
        assert!(!reqs.is_empty());
        for r in &reqs {
            assert!(r.queue_delay_ms >= 0.0 && r.queue_delay_ms <= 3000.0);
        }
        let all: usize = t
            .records
            .iter()
            .filter(|r| r.arrival_ms < 3000.0)
            .count();
        assert_eq!(reqs.len(), all);
    }

    #[test]
    fn load_missing_file_errors() {
        assert!(Trace::load("/nonexistent/trace.json").is_err());
    }
}
