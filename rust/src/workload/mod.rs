//! Workload generation: the request populations of §IV.
//!
//! Numerical defaults follow the paper: requested accuracy
//! `A_i ~ N(45%, 10%)` truncated to [0, 100]; requested delay
//! `C_i ~ N(1000 ms, 4000 ms)` truncated to [0, Max_cs]; queuing delay
//! `T^q ~ U(0, 50) ms`; services uniform over K; covering edge uniform
//! over the edge servers; equal weights `w_a = w_c = 1`.

pub mod trace;

use crate::model::request::Request;
use crate::model::server::ServerId;
use crate::model::ProblemInstance;
use crate::model::service::{CatalogParams, Placement, ServiceCatalog};
use crate::model::topology::{Topology, TopologyParams};
use crate::util::rng::Rng;

/// Distribution parameters for one request population.
#[derive(Clone, Debug)]
pub struct WorkloadParams {
    pub num_requests: usize,
    /// A_i mean / std (percent).
    pub accuracy_mean_pct: f64,
    pub accuracy_std_pct: f64,
    /// C_i mean / std (ms).
    pub deadline_mean_ms: f64,
    pub deadline_std_ms: f64,
    /// T^q upper bound (ms), uniform from 0.
    pub queue_delay_max_ms: f64,
    /// Satisfaction weights (paper: both 1).
    pub w_accuracy: f64,
    pub w_completion: f64,
    /// Payload size band (bytes) for the serving path.
    pub payload_lo_bytes: u64,
    pub payload_hi_bytes: u64,
    /// Hard cap used to truncate C_i (the system's Max_cs).
    pub max_completion_ms: f64,
}

impl Default for WorkloadParams {
    fn default() -> Self {
        WorkloadParams {
            num_requests: 100,
            accuracy_mean_pct: 45.0,
            accuracy_std_pct: 10.0,
            deadline_mean_ms: 1000.0,
            deadline_std_ms: 4000.0,
            queue_delay_max_ms: 50.0,
            w_accuracy: 1.0,
            w_completion: 1.0,
            payload_lo_bytes: 8_000,
            payload_hi_bytes: 20_000,
            max_completion_ms: 12_000.0,
        }
    }
}

/// Draw one request population against a topology/catalog.
pub fn generate_requests(
    params: &WorkloadParams,
    num_services: usize,
    edge_ids: &[ServerId],
    rng: &mut Rng,
) -> Vec<Request> {
    assert!(!edge_ids.is_empty(), "need at least one edge server");
    (0..params.num_requests)
        .map(|i| {
            let covering = *rng.choose(edge_ids).unwrap(); // lint:allow(unwrap) — non-empty asserted above
            let a = rng.normal_clamped(params.accuracy_mean_pct, params.accuracy_std_pct, 0.0, 100.0);
            let c = rng.normal_clamped(
                params.deadline_mean_ms,
                params.deadline_std_ms,
                0.0,
                params.max_completion_ms,
            );
            Request::new(i, rng.index(num_services), covering.0)
                .with_qos(a, c)
                .with_weights(params.w_accuracy, params.w_completion)
                .with_queue_delay(rng.uniform(0.0, params.queue_delay_max_ms))
                .with_payload(rng.u64_range(params.payload_lo_bytes, params.payload_hi_bytes))
        })
        .collect()
}

/// Draw an index with probability proportional to `weights` (negative,
/// NaN and infinite entries count as zero). Falls back to a uniform draw
/// when no positive weight remains, so callers never lose a request to a
/// fully-drained weight vector. Exactly one RNG draw either way — the
/// scenario engine's `UserMobility` re-homing relies on that for
/// reproducibility.
pub fn pick_weighted(weights: &[f64], rng: &mut Rng) -> usize {
    assert!(!weights.is_empty(), "pick_weighted needs at least one weight");
    let live = |w: &f64| w.is_finite() && *w > 0.0;
    let total: f64 = weights.iter().filter(|w| live(w)).sum();
    if total <= 0.0 {
        return rng.index(weights.len());
    }
    let mut r = rng.f64() * total;
    let mut last = 0;
    for (i, w) in weights.iter().enumerate() {
        if live(w) {
            last = i;
            r -= *w;
            if r <= 0.0 {
                return i;
            }
        }
    }
    last // float round-off: land on the last live weight
}

/// Everything needed to instantiate one full numerical scenario.
#[derive(Clone, Debug, Default)]
pub struct ScenarioParams {
    pub topology: TopologyParams,
    pub catalog: CatalogParams,
    pub workload: WorkloadParams,
}

/// Build a complete `ProblemInstance` for one Monte-Carlo draw.
pub fn build_instance(params: &ScenarioParams, rng: &mut Rng) -> ProblemInstance<'static> {
    let topology = Topology::paper_default(&params.topology, rng);
    let catalog = ServiceCatalog::synthetic(&params.catalog, rng);
    let classes: Vec<_> = topology.servers.iter().map(|s| s.class).collect();
    let placement = Placement::random(&catalog, &classes, rng);
    let edge_ids = topology.edge_ids();
    let requests = generate_requests(&params.workload, catalog.num_services, &edge_ids, rng);
    ProblemInstance::new(topology, catalog, placement, requests)
        .with_normalization(100.0, params.workload.max_completion_ms)
}

impl Rng {
    /// Uniform u64 in `[lo, hi]`.
    pub fn u64_range(&mut self, lo: u64, hi: u64) -> u64 {
        if hi <= lo {
            return lo;
        }
        lo + self.below(hi - lo + 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_requested_count_with_valid_fields() {
        let mut rng = Rng::new(1);
        let edges = vec![ServerId(0), ServerId(1), ServerId(2)];
        let reqs = generate_requests(&WorkloadParams::default(), 10, &edges, &mut rng);
        assert_eq!(reqs.len(), 100);
        for r in &reqs {
            assert!((0.0..=100.0).contains(&r.min_accuracy_pct));
            assert!((0.0..=12_000.0).contains(&r.max_completion_ms));
            assert!((0.0..=50.0).contains(&r.queue_delay_ms));
            assert!(r.service.0 < 10);
            assert!(edges.contains(&r.covering));
            assert!((8_000..=20_000).contains(&r.payload_bytes));
        }
    }

    #[test]
    fn accuracy_distribution_centered() {
        let mut rng = Rng::new(2);
        let edges = vec![ServerId(0)];
        let params = WorkloadParams { num_requests: 20_000, ..Default::default() };
        let reqs = generate_requests(&params, 5, &edges, &mut rng);
        let mean: f64 =
            reqs.iter().map(|r| r.min_accuracy_pct).sum::<f64>() / reqs.len() as f64;
        assert!((mean - 45.0).abs() < 0.5, "mean={mean}");
    }

    #[test]
    fn deadline_truncation_shifts_mean_up() {
        // N(1000, 4000) truncated to [0, 12000]: mass below 0 folds to 0,
        // so the realized mean is > 1000 but far below the cap.
        let mut rng = Rng::new(3);
        let edges = vec![ServerId(0)];
        let params = WorkloadParams { num_requests: 20_000, ..Default::default() };
        let reqs = generate_requests(&params, 5, &edges, &mut rng);
        let mean: f64 =
            reqs.iter().map(|r| r.max_completion_ms).sum::<f64>() / reqs.len() as f64;
        assert!(mean > 1500.0 && mean < 4000.0, "mean={mean}");
    }

    #[test]
    fn build_instance_is_valid_and_paper_sized() {
        let mut rng = Rng::new(4);
        let inst = build_instance(&ScenarioParams::default(), &mut rng);
        inst.validate().unwrap();
        assert_eq!(inst.num_servers(), 10);
        assert_eq!(inst.num_requests(), 100);
        assert_eq!(inst.catalog.num_services, 100);
        assert_eq!(inst.catalog.num_tiers, 10);
        assert_eq!(inst.max_completion_ms, 12_000.0);
    }

    #[test]
    fn build_instance_deterministic_per_seed() {
        let a = build_instance(&ScenarioParams::default(), &mut Rng::new(9));
        let b = build_instance(&ScenarioParams::default(), &mut Rng::new(9));
        for (x, y) in a.requests.iter().zip(b.requests.iter()) {
            assert_eq!(x.min_accuracy_pct, y.min_accuracy_pct);
            assert_eq!(x.covering, y.covering);
        }
    }

    #[test]
    fn pick_weighted_respects_mass_and_masks() {
        let mut rng = Rng::new(6);
        let weights = [0.0, 3.0, 0.0, 1.0];
        let mut counts = [0usize; 4];
        for _ in 0..4000 {
            counts[pick_weighted(&weights, &mut rng)] += 1;
        }
        assert_eq!(counts[0], 0);
        assert_eq!(counts[2], 0);
        let ratio = counts[1] as f64 / counts[3] as f64;
        assert!((2.0..4.5).contains(&ratio), "expected ~3:1, got {ratio}");
    }

    #[test]
    fn pick_weighted_zero_mass_falls_back_to_uniform() {
        let mut rng = Rng::new(7);
        let weights = [0.0, 0.0, 0.0];
        let mut seen = [false; 3];
        for _ in 0..200 {
            seen[pick_weighted(&weights, &mut rng)] = true;
        }
        assert!(seen.iter().all(|s| *s), "fallback must cover every index");
    }

    #[test]
    fn u64_range_bounds() {
        let mut rng = Rng::new(5);
        for _ in 0..1000 {
            let v = rng.u64_range(10, 12);
            assert!((10..=12).contains(&v));
        }
        assert_eq!(rng.u64_range(5, 5), 5);
    }
}
