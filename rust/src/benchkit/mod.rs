//! In-tree micro/macro-benchmark harness (the offline registry has no
//! criterion). Provides warmup + repeated timed runs, robust summary
//! stats, and markdown reporting; the `cargo bench` targets are plain
//! `harness = false` binaries built on this.

use crate::util::stats::{percentile, Accumulator};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Fan `f` out over `items` on `threads` scoped `std::thread` workers.
///
/// Work is pulled from a shared atomic cursor (so uneven item costs load-
/// balance), but results come back **in item order** regardless of which
/// worker ran what — callers aggregate deterministically. `f` receives
/// `(index, &item)`. Panics in `f` propagate when the scope joins.
///
/// This is the substrate for the scenario sweep runner (seeds × policies
/// DES fan-out) and any future embarrassingly-parallel harness work.
pub fn parallel_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    if items.is_empty() {
        return Vec::new();
    }
    let threads = threads.max(1).min(items.len());
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<R>>> = items.iter().map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let r = f(i, &items[i]);
                *slots[i].lock().unwrap() = Some(r);
            });
        }
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("parallel_map worker completed")) // lint:allow(unwrap) — propagate worker panics
        .collect()
}

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ms: f64,
    pub std_ms: f64,
    pub min_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub max_ms: f64,
    /// Optional throughput annotation (items/s), when `items_per_iter`
    /// was set.
    pub throughput: Option<f64>,
}

/// Harness configuration.
#[derive(Clone, Copy, Debug)]
pub struct Bencher {
    pub warmup_iters: usize,
    pub iters: usize,
    /// Per-iteration item count for throughput reporting.
    pub items_per_iter: Option<f64>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { warmup_iters: 2, iters: 10, items_per_iter: None }
    }
}

impl Bencher {
    pub fn new(warmup_iters: usize, iters: usize) -> Bencher {
        Bencher { warmup_iters, iters, items_per_iter: None }
    }

    pub fn with_items(mut self, items: f64) -> Bencher {
        self.items_per_iter = Some(items);
        self
    }

    /// Time `f` (a full benchmark iteration). The closure's return value
    /// is black-boxed to keep the optimizer honest.
    pub fn run<T, F: FnMut() -> T>(&self, name: &str, mut f: F) -> BenchResult {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.iters);
        let mut acc = Accumulator::new();
        for _ in 0..self.iters.max(1) {
            let t0 = Instant::now();
            std::hint::black_box(f());
            let ms = t0.elapsed().as_secs_f64() * 1e3;
            samples.push(ms);
            acc.push(ms);
        }
        BenchResult {
            name: name.to_string(),
            iters: self.iters,
            mean_ms: acc.mean(),
            std_ms: acc.std(),
            min_ms: acc.min(),
            p50_ms: percentile(&samples, 0.5),
            p95_ms: percentile(&samples, 0.95),
            max_ms: acc.max(),
            throughput: self.items_per_iter.map(|n| n / (acc.mean() / 1e3)),
        }
    }
}

/// Render a set of results as a markdown table.
pub fn report(title: &str, results: &[BenchResult]) -> String {
    let mut out = format!("\n## {title}\n\n");
    out.push_str("| case | iters | mean (ms) | std | min | p50 | p95 | max | throughput |\n");
    out.push_str("|---|---|---|---|---|---|---|---|---|\n");
    for r in results {
        out.push_str(&format!(
            "| {} | {} | {:.3} | {:.3} | {:.3} | {:.3} | {:.3} | {:.3} | {} |\n",
            r.name,
            r.iters,
            r.mean_ms,
            r.std_ms,
            r.min_ms,
            r.p50_ms,
            r.p95_ms,
            r.max_ms,
            r.throughput
                .map(|t| format!("{t:.1}/s"))
                .unwrap_or_else(|| "-".into()),
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_collects_stats() {
        let b = Bencher::new(1, 5);
        let r = b.run("sleepy", || std::thread::sleep(std::time::Duration::from_millis(2)));
        assert_eq!(r.iters, 5);
        assert!(r.mean_ms >= 2.0);
        assert!(r.min_ms <= r.p50_ms && r.p50_ms <= r.max_ms);
    }

    #[test]
    fn throughput_annotation() {
        let b = Bencher::new(0, 3).with_items(100.0);
        let r = b.run("t", || std::thread::sleep(std::time::Duration::from_millis(1)));
        let t = r.throughput.unwrap();
        assert!(t > 1000.0 && t < 100_000_0.0, "t={t}");
    }

    #[test]
    fn report_renders_all_rows() {
        let b = Bencher::new(0, 2);
        let rs = vec![b.run("a", || 1 + 1), b.run("b", || 2 + 2)];
        let md = report("title", &rs);
        assert!(md.contains("## title"));
        assert!(md.contains("| a |"));
        assert!(md.contains("| b |"));
    }

    #[test]
    fn parallel_map_preserves_item_order() {
        let items: Vec<usize> = (0..100).collect();
        let out = parallel_map(&items, 7, |i, x| {
            assert_eq!(i, *x);
            x * 2
        });
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_thread_count_does_not_change_results() {
        let items: Vec<u64> = (0..40).collect();
        let f = |_: usize, x: &u64| x.wrapping_mul(0x9E3779B97F4A7C15) >> 7;
        let a = parallel_map(&items, 1, f);
        let b = parallel_map(&items, 16, f);
        assert_eq!(a, b);
    }

    #[test]
    fn parallel_map_handles_empty_and_oversized_thread_counts() {
        let empty: Vec<u8> = Vec::new();
        assert!(parallel_map(&empty, 4, |_, x| *x).is_empty());
        let one = [41u8];
        assert_eq!(parallel_map(&one, 999, |_, x| x + 1), vec![42]);
    }
}
