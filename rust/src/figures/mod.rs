//! Figure-regeneration harness: every panel of the paper's Fig. 1 plus
//! the in-text GUS-vs-optimal comparison, as parameter sweeps that print
//! the same series the paper plots, and the scenario-engine
//! satisfaction-vs-time panels. See DESIGN.md §Experiment-index.
//!
//! Numerical panels (a–d) sweep one workload parameter of the §IV
//! Monte-Carlo setup; testbed panels (e–h) are produced by
//! `serving::experiment` over the live serving runtime and re-exported
//! here for the benches.

use crate::coordinator::gus::Gus;
use crate::coordinator::ilp::BranchAndBound;
use crate::coordinator::Scheduler;
use crate::metrics::Series;
use crate::model::service::CatalogParams;
use crate::model::topology::TopologyParams;
use crate::sim::{MonteCarlo, PolicyStats};
use crate::util::rng::Rng;
use crate::workload::{build_instance, ScenarioParams, WorkloadParams};

/// The numerical panels of Fig. 1.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NumericalFigure {
    /// (a) satisfied % vs requested-delay mean.
    Fig1a,
    /// (b) satisfied % vs requested-accuracy mean.
    Fig1b,
    /// (c) satisfied % vs number of requests.
    Fig1c,
    /// (d) satisfied % vs admission-queue delay bound.
    Fig1d,
}

impl NumericalFigure {
    pub fn parse(s: &str) -> Option<NumericalFigure> {
        match s {
            "fig1a" | "a" => Some(NumericalFigure::Fig1a),
            "fig1b" | "b" => Some(NumericalFigure::Fig1b),
            "fig1c" | "c" => Some(NumericalFigure::Fig1c),
            "fig1d" | "d" => Some(NumericalFigure::Fig1d),
            _ => None,
        }
    }

    pub fn id(&self) -> &'static str {
        match self {
            NumericalFigure::Fig1a => "fig1a",
            NumericalFigure::Fig1b => "fig1b",
            NumericalFigure::Fig1c => "fig1c",
            NumericalFigure::Fig1d => "fig1d",
        }
    }

    /// The swept x values (paper-plausible ranges).
    pub fn default_sweep(&self) -> Vec<f64> {
        match self {
            NumericalFigure::Fig1a => vec![500.0, 1000.0, 2000.0, 3000.0, 4000.0, 6000.0, 8000.0],
            NumericalFigure::Fig1b => vec![30.0, 40.0, 45.0, 50.0, 60.0, 70.0, 80.0],
            NumericalFigure::Fig1c => vec![25.0, 50.0, 100.0, 150.0, 200.0, 300.0],
            NumericalFigure::Fig1d => vec![0.0, 50.0, 100.0, 250.0, 500.0, 1000.0, 2000.0],
        }
    }

    pub fn x_label(&self) -> &'static str {
        match self {
            NumericalFigure::Fig1a => "requested delay mean (ms)",
            NumericalFigure::Fig1b => "requested accuracy mean (%)",
            NumericalFigure::Fig1c => "number of requests",
            NumericalFigure::Fig1d => "max queue delay (ms)",
        }
    }

    /// Apply one sweep value to the scenario.
    pub fn apply(&self, scenario: &mut ScenarioParams, x: f64) {
        match self {
            NumericalFigure::Fig1a => scenario.workload.deadline_mean_ms = x,
            NumericalFigure::Fig1b => scenario.workload.accuracy_mean_pct = x,
            NumericalFigure::Fig1c => scenario.workload.num_requests = x as usize,
            NumericalFigure::Fig1d => scenario.workload.queue_delay_max_ms = x,
        }
    }
}

/// Configuration of a numerical-figure run.
#[derive(Clone, Debug)]
pub struct NumericalConfig {
    pub base: ScenarioParams,
    pub runs: usize,
    pub seed: u64,
    pub threads: usize,
}

impl Default for NumericalConfig {
    fn default() -> Self {
        NumericalConfig {
            base: ScenarioParams::default(),
            runs: 500,
            seed: 7,
            threads: crate::sim::montecarlo::default_threads(),
        }
    }
}

impl NumericalConfig {
    /// A reduced-size config for smoke tests / CI.
    pub fn quick() -> NumericalConfig {
        NumericalConfig {
            base: ScenarioParams {
                topology: TopologyParams { num_edge: 4, num_cloud: 1, ..Default::default() },
                catalog: CatalogParams { num_services: 10, num_tiers: 4, ..Default::default() },
                workload: WorkloadParams { num_requests: 30, ..Default::default() },
            },
            runs: 12,
            seed: 3,
            threads: 4,
        }
    }
}

/// Run one numerical panel: sweep x, Monte-Carlo each point, collect the
/// satisfied-% series per policy.
pub fn run_numerical(figure: NumericalFigure, cfg: &NumericalConfig) -> Series {
    run_numerical_sweep(figure, cfg, &figure.default_sweep())
}

pub fn run_numerical_sweep(
    figure: NumericalFigure,
    cfg: &NumericalConfig,
    sweep: &[f64],
) -> Series {
    let mut per_policy: Vec<(String, Vec<f64>, Vec<f64>)> = Vec::new();
    for &x in sweep {
        let mut scenario = cfg.base.clone();
        figure.apply(&mut scenario, x);
        let mc = MonteCarlo {
            scenario,
            runs: cfg.runs,
            base_seed: cfg.seed,
            threads: cfg.threads,
        };
        let stats = mc.run();
        record_point(&mut per_policy, &stats);
    }
    let mut series = Series::new(figure.x_label(), "satisfied users (%)", sweep.to_vec());
    for (name, ys, cis) in per_policy {
        series.push_policy(&name, ys, cis);
    }
    series
}

fn record_point(per_policy: &mut Vec<(String, Vec<f64>, Vec<f64>)>, stats: &[PolicyStats]) {
    if per_policy.is_empty() {
        for s in stats {
            per_policy.push((s.name.clone(), Vec::new(), Vec::new()));
        }
    }
    for (slot, s) in per_policy.iter_mut().zip(stats.iter()) {
        debug_assert_eq!(slot.0, s.name);
        slot.1.push(s.satisfied_pct.mean());
        slot.2.push(s.satisfied_pct.ci95());
    }
}

/// Satisfaction-vs-time under a named built-in scenario: run the DES for
/// each policy × seed (parallel sweep), resample each run's per-frame
/// series onto the decision-frame grid, and report mean ± 95% CI per
/// policy. The dynamic-world analogue of the Fig. 1 panels — see
/// DESIGN.md §Experiment-index.
pub fn run_scenario_figure(
    name: &str,
    base: &crate::sim::DesConfig,
    policies: &[&str],
    num_seeds: usize,
) -> anyhow::Result<Series> {
    let script = crate::scenario::Script::builtin(
        name,
        base.horizon_ms,
        base.scenario.topology.num_edge,
    )
    .ok_or_else(|| {
        anyhow::anyhow!(
            "unknown scenario {name:?} (built-ins: {})",
            crate::scenario::Script::builtin_names().join(", ")
        )
    })?;
    for p in policies {
        if crate::coordinator::scheduler_by_name(p).is_none() {
            anyhow::bail!("unknown policy {p:?}");
        }
    }
    let mut cfg = crate::scenario::SweepConfig {
        base: base.clone(),
        policies: policies.iter().map(|p| p.to_string()).collect(),
        num_seeds,
        ..Default::default()
    };
    cfg.base.script = Some(script);
    let sweeps = crate::scenario::run_sweep(&cfg);
    Ok(crate::scenario::timeline_series(&cfg, &sweeps))
}

/// The in-text claim: GUS attains ~90% of the CPLEX optimum on small
/// cases. Sweeps instance size; reports mean GUS/OPT objective ratio
/// (only over instances where OPT > 0) plus both absolute objectives.
pub struct OptimalGapResult {
    pub series: Series,
    /// Overall mean ratio across all sizes/instances.
    pub mean_ratio: f64,
    /// Fraction of instances proven exact by the B&B.
    pub exact_fraction: f64,
}

pub fn run_optimal_gap(sizes: &[usize], instances_per_size: usize, seed: u64) -> OptimalGapResult {
    let mut xs = Vec::new();
    let mut ratio_ys = Vec::new();
    let mut ratio_cis = Vec::new();
    let mut gus_ys = Vec::new();
    let mut opt_ys = Vec::new();
    let mut all_ratios = crate::util::stats::Accumulator::new();
    let mut exact = 0u64;
    let mut total = 0u64;
    for &n in sizes {
        let mut ratios = crate::util::stats::Accumulator::new();
        let mut gus_acc = crate::util::stats::Accumulator::new();
        let mut opt_acc = crate::util::stats::Accumulator::new();
        for i in 0..instances_per_size {
            let mut rng = Rng::new(seed ^ ((n as u64) << 32) ^ i as u64);
            let scenario = ScenarioParams {
                topology: TopologyParams { num_edge: 3, num_cloud: 1, ..Default::default() },
                catalog: CatalogParams { num_services: 4, num_tiers: 3, ..Default::default() },
                workload: WorkloadParams {
                    num_requests: n,
                    // Generous deadlines so feasibility is decided by the
                    // capacities, not the QoS thresholds.
                    deadline_mean_ms: 6_000.0,
                    deadline_std_ms: 2_000.0,
                    ..Default::default()
                },
            };
            let mut inst = build_instance(&scenario, &mut rng);
            // Tighten capacities so requests genuinely compete: with the
            // class defaults the greedy is trivially optimal (the paper's
            // CPLEX comparison likewise used constrained small cases).
            for s in &mut inst.topology.to_mut().servers {
                s.gamma = if s.is_cloud() { (n as f64 / 3.0).max(2.0) } else { 2.0 };
                s.eta = 2.0;
            }
            let opt = BranchAndBound::default().solve(&inst);
            let gus = Gus::default().schedule(&inst, &mut rng);
            total += 1;
            if opt.exact {
                exact += 1;
            }
            let o = opt.schedule.objective();
            let g = gus.objective();
            gus_acc.push(g);
            opt_acc.push(o);
            if o > 1e-9 {
                let r = (g / o).min(1.0);
                ratios.push(r);
                all_ratios.push(r);
            }
        }
        xs.push(n as f64);
        ratio_ys.push(ratios.mean());
        ratio_cis.push(ratios.ci95());
        gus_ys.push(gus_acc.mean());
        opt_ys.push(opt_acc.mean());
    }
    let nan = vec![f64::NAN; xs.len()];
    let mut series = Series::new("requests (N)", "GUS/OPT objective ratio", xs);
    series.push_policy("gus/opt", ratio_ys, ratio_cis);
    series.push_policy("gus objective", gus_ys, nan.clone());
    series.push_policy("opt objective", opt_ys, nan);
    OptimalGapResult {
        series,
        mean_ratio: all_ratios.mean(),
        exact_fraction: exact as f64 / total.max(1) as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_ids() {
        assert_eq!(NumericalFigure::parse("fig1a"), Some(NumericalFigure::Fig1a));
        assert_eq!(NumericalFigure::parse("d"), Some(NumericalFigure::Fig1d));
        assert_eq!(NumericalFigure::parse("fig1e"), None);
    }

    #[test]
    fn apply_hits_right_knob() {
        let mut s = ScenarioParams::default();
        NumericalFigure::Fig1a.apply(&mut s, 1234.0);
        assert_eq!(s.workload.deadline_mean_ms, 1234.0);
        NumericalFigure::Fig1b.apply(&mut s, 66.0);
        assert_eq!(s.workload.accuracy_mean_pct, 66.0);
        NumericalFigure::Fig1c.apply(&mut s, 77.0);
        assert_eq!(s.workload.num_requests, 77);
        NumericalFigure::Fig1d.apply(&mut s, 88.0);
        assert_eq!(s.workload.queue_delay_max_ms, 88.0);
    }

    #[test]
    fn quick_sweep_produces_series() {
        let cfg = NumericalConfig::quick();
        let series = run_numerical_sweep(NumericalFigure::Fig1c, &cfg, &[20.0, 40.0]);
        assert_eq!(series.xs, vec![20.0, 40.0]);
        assert_eq!(series.policies.len(), 6);
        for (_, ys, _) in &series.policies {
            assert_eq!(ys.len(), 2);
            assert!(ys.iter().all(|y| (0.0..=100.0).contains(y)));
        }
    }

    #[test]
    fn fig1a_satisfaction_increases_with_deadline_for_gus() {
        let cfg = NumericalConfig::quick();
        let series = run_numerical_sweep(NumericalFigure::Fig1a, &cfg, &[500.0, 8000.0]);
        let gus = &series.policies.iter().find(|(n, _, _)| n == "gus").unwrap().1;
        assert!(gus[1] > gus[0], "more delay budget must help: {gus:?}");
    }

    #[test]
    fn scenario_figure_produces_time_series() {
        let base = crate::sim::DesConfig {
            scenario: ScenarioParams {
                topology: TopologyParams { num_edge: 3, num_cloud: 1, ..Default::default() },
                catalog: CatalogParams { num_services: 8, num_tiers: 3, ..Default::default() },
                workload: WorkloadParams::default(),
            },
            horizon_ms: 18_000.0,
            arrival_rate_per_s: 4.0,
            ..Default::default()
        };
        let s = run_scenario_figure("flash-crowd", &base, &["gus"], 2).unwrap();
        assert_eq!(s.policies.len(), 1);
        assert_eq!(s.xs.len(), 6, "18 s horizon / 3 s frames");
        assert!(run_scenario_figure("no-such-scenario", &base, &["gus"], 1).is_err());
        assert!(run_scenario_figure("flash-crowd", &base, &["no-such-policy"], 1).is_err());
    }

    #[test]
    fn optimal_gap_near_one_on_small() {
        let r = run_optimal_gap(&[3, 5], 4, 11);
        assert!(r.exact_fraction > 0.99);
        assert!(r.mean_ratio > 0.8, "greedy should be near-optimal, got {}", r.mean_ratio);
        assert!(r.mean_ratio <= 1.0 + 1e-9);
    }
}
