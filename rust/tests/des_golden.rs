//! Golden same-seed determinism tests for the allocation-free DES hot
//! path: `Des::run` (borrowed frame instances, pooled buffers) must be
//! byte-identical to `Des::run_reference` (the pre-pooling
//! clone-the-world decide loop, kept in-tree as the oracle) — for the
//! plain world and for every built-in scenario script, with and without
//! a recorder attached.

use edgeus::coordinator::gus::Gus;
use edgeus::model::service::CatalogParams;
use edgeus::model::topology::TopologyParams;
use edgeus::obs::Recorder;
use edgeus::scenario::Script;
use edgeus::sim::{Des, DesConfig, DesReport};
use edgeus::workload::{ScenarioParams, WorkloadParams};

const HORIZON_MS: f64 = 30_000.0;

/// Small world, overloaded enough that drops and queue-full decisions
/// occur, with a non-trivial deadline spread.
fn cfg(script: Option<&str>) -> DesConfig {
    let topology = TopologyParams { num_edge: 3, num_cloud: 1, ..Default::default() };
    let num_edge = topology.num_edge;
    DesConfig {
        scenario: ScenarioParams {
            topology,
            catalog: CatalogParams { num_services: 10, num_tiers: 4, ..Default::default() },
            workload: WorkloadParams {
                deadline_mean_ms: 4000.0,
                deadline_std_ms: 2000.0,
                ..Default::default()
            },
        },
        horizon_ms: HORIZON_MS,
        arrival_rate_per_s: 40.0,
        script: script.map(|name| {
            Script::builtin(name, HORIZON_MS, num_edge)
                .unwrap_or_else(|| panic!("unknown builtin {name}"))
        }),
        ..Default::default()
    }
}

/// Every script variant under test: the plain world plus all builtins.
fn variants() -> Vec<Option<&'static str>> {
    let mut v = vec![None];
    v.extend(Script::builtin_names().iter().map(|n| Some(*n)));
    v
}

#[test]
fn pooled_run_matches_reference_for_every_builtin_scenario() {
    let gus = Gus::default();
    for script in variants() {
        let pooled = Des::new(cfg(script), &gus).run();
        let reference = Des::new(cfg(script), &gus).run_reference();
        assert!(pooled.generated > 0, "{script:?}: empty run proves nothing");
        pooled.check_conservation().unwrap_or_else(|e| panic!("{script:?}: {e}"));
        assert_eq!(
            pooled.to_json().dump(),
            reference.to_json().dump(),
            "divergence under {script:?}"
        );
    }
}

#[test]
fn pooled_run_matches_reference_with_disabled_recorder() {
    let gus = Gus::default();
    for script in variants() {
        let rec_a = Recorder::disabled();
        let rec_b = Recorder::disabled();
        let pooled = Des::new(cfg(script), &gus).with_recorder(&rec_a).run();
        let reference = Des::new(cfg(script), &gus).with_recorder(&rec_b).run_reference();
        assert_eq!(
            pooled.to_json().dump(),
            reference.to_json().dump(),
            "divergence under {script:?} with a disabled recorder"
        );
    }
}

/// `schedule_wall_us` is genuine wall-clock, so instrumented dumps are
/// compared with it zeroed; everything else must match exactly.
fn zero_wall(mut report: DesReport) -> DesReport {
    for e in &mut report.explain {
        e.schedule_wall_us = 0.0;
    }
    report
}

#[test]
fn pooled_run_matches_reference_with_enabled_recorder() {
    let gus = Gus::default();
    for script in variants() {
        let rec_a = Recorder::enabled(1 << 14);
        let rec_b = Recorder::enabled(1 << 14);
        let pooled = zero_wall(Des::new(cfg(script), &gus).with_recorder(&rec_a).run());
        let reference =
            zero_wall(Des::new(cfg(script), &gus).with_recorder(&rec_b).run_reference());
        assert!(!pooled.explain.is_empty(), "{script:?}: instrumented run must explain");
        assert_eq!(
            pooled.to_json().dump(),
            reference.to_json().dump(),
            "divergence under {script:?} with an enabled recorder"
        );
    }
}

#[test]
fn cached_gus_matches_uncached_gus_byte_for_byte() {
    let cached = Gus::default();
    let uncached = Gus::default().uncached();
    for script in variants() {
        let with_cache = Des::new(cfg(script), &cached).run();
        let without = Des::new(cfg(script), &uncached).run();
        assert!(
            with_cache.cache_hits > 0,
            "{script:?}: cache never hit — the test is not exercising the cached walk"
        );
        assert!(with_cache.cache_misses > 0, "{script:?}: cold start must miss at least once");
        assert_eq!(
            without.cache_hits + without.cache_misses,
            0,
            "{script:?}: the uncached policy must never consult the rank cache"
        );
        assert_eq!(
            with_cache.to_json().dump(),
            without.to_json().dump(),
            "rank-cache walk diverged from enumerate+sort under {script:?}"
        );
        if script.is_none() {
            assert!(
                with_cache.cache_hit_rate() > 0.9,
                "plain-world steady-state hit rate {:.3} ≤ 0.9",
                with_cache.cache_hit_rate()
            );
        }
    }
}

#[test]
fn pooled_run_is_deterministic_across_repeats() {
    let gus = Gus::default();
    for script in variants() {
        let a = Des::new(cfg(script), &gus).run().to_json().dump();
        let b = Des::new(cfg(script), &gus).run().to_json().dump();
        assert_eq!(a, b, "same-seed rerun differs under {script:?}");
    }
}
