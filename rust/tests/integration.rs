//! Integration tests across model → coordinator → sim → config, plus the
//! PJRT runtime against the real artifacts (skipped with a notice when
//! `artifacts/` has not been built).

use edgeus::config;
use edgeus::coordinator::us::{validate_schedule, ConstraintMode};
use edgeus::prelude::*;
use edgeus::runtime::InferenceEngine;
use edgeus::util::json::Json;

fn artifacts_dir() -> Option<String> {
    let dir = std::env::var("EDGEUS_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    std::path::Path::new(&format!("{dir}/manifest.json")).exists().then_some(dir)
}

// ---------------------------------------------------------------- runtime

#[test]
fn runtime_loads_and_infers_every_tier() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    };
    let engine = InferenceEngine::load_filtered(&dir, |a| a.batch == 1).unwrap();
    for tier in engine.manifest.tiers() {
        let images = vec![0.25f32; 32 * 32 * 3];
        let r = engine.infer_tier(&tier, 1, &images).unwrap();
        assert_eq!(r.logits.len(), 10, "{tier}: wrong logit count");
        assert!(r.logits.iter().all(|x| x.is_finite()), "{tier}: non-finite logits");
        assert!(r.execute_ms > 0.0);
        assert!(r.predictions()[0] < 10);
    }
}

#[test]
fn runtime_inference_is_deterministic() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    };
    let engine = InferenceEngine::load_filtered(&dir, |a| a.tier == "tiny" && a.batch == 1)
        .unwrap();
    let images: Vec<f32> = (0..32 * 32 * 3).map(|i| (i % 255) as f32 / 255.0).collect();
    let a = engine.infer_tier("tiny", 1, &images).unwrap();
    let b = engine.infer_tier("tiny", 1, &images).unwrap();
    assert_eq!(a.logits, b.logits);
}

#[test]
fn runtime_batch_matches_single() {
    // Row i of a batch-4 execution equals 4 independent batch-1 runs.
    let Some(dir) = artifacts_dir() else {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    };
    let engine = InferenceEngine::load_filtered(&dir, |a| a.tier == "small").unwrap();
    let mut rng = Rng::new(5);
    let one = 32 * 32 * 3;
    let images: Vec<f32> = (0..4 * one).map(|_| rng.f64() as f32).collect();
    let batched = engine.infer_tier("small", 4, &images).unwrap();
    for i in 0..4 {
        let single = engine
            .infer_tier("small", 1, &images[i * one..(i + 1) * one])
            .unwrap();
        for (a, b) in batched.logits[i * 10..(i + 1) * 10].iter().zip(single.logits.iter()) {
            assert!((a - b).abs() < 1e-4, "row {i}: {a} vs {b}");
        }
    }
}

#[test]
fn runtime_rejects_wrong_input_shape() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    };
    let engine = InferenceEngine::load_filtered(&dir, |a| a.tier == "tiny" && a.batch == 1)
        .unwrap();
    assert!(engine.infer_tier("tiny", 1, &[0.0; 10]).is_err());
    assert!(engine.infer_tier("nope", 1, &[0.0; 3072]).is_err());
}

#[test]
fn manifest_profiles_are_monotone_ladder() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    };
    let manifest = edgeus::runtime::Manifest::load(&dir).unwrap();
    let tiers = manifest.tiers();
    assert!(tiers.len() >= 3, "need a real tier ladder, got {tiers:?}");
    let accs: Vec<f64> = tiers
        .iter()
        .map(|t| manifest.find(t, 1).unwrap().profile_accuracy_pct)
        .collect();
    let flops: Vec<u64> = tiers
        .iter()
        .map(|t| manifest.find(t, 1).unwrap().flops_per_image)
        .collect();
    for i in 1..accs.len() {
        assert!(accs[i] > accs[i - 1], "accuracy ladder must ascend");
        assert!(flops[i] > flops[i - 1], "flops ladder must ascend");
    }
}

// ------------------------------------------------------ coordinator + sim

#[test]
fn full_monte_carlo_pipeline_produces_sane_ordering() {
    let mc = MonteCarlo {
        runs: 32,
        base_seed: 11,
        threads: 4,
        ..Default::default()
    };
    let stats = mc.run();
    let by = |n: &str| stats.iter().find(|s| s.name == n).unwrap();
    let gus = by("gus");
    // GUS dominates the naive baselines on the paper-default scenario.
    for baseline in ["random", "offload-all", "local-all"] {
        assert!(
            gus.satisfied_pct.mean() >= by(baseline).satisfied_pct.mean(),
            "GUS {} < {} {}",
            gus.satisfied_pct.mean(),
            baseline,
            by(baseline).satisfied_pct.mean()
        );
    }
    // The headline claim: ≥ 1.5x the mean of the naive baselines.
    let naive_mean = (by("random").satisfied_pct.mean()
        + by("offload-all").satisfied_pct.mean()
        + by("local-all").satisfied_pct.mean())
        / 3.0;
    assert!(
        gus.satisfied_pct.mean() >= 1.5 * naive_mean,
        "paper claims ≥50% improvement: GUS {:.1} vs naive mean {:.1}",
        gus.satisfied_pct.mean(),
        naive_mean
    );
}

#[test]
fn every_policy_returns_constraint_valid_schedules() {
    let mut rng = Rng::new(21);
    let inst = build_instance(&ScenarioParams::default(), &mut rng);
    for sched in all_schedulers() {
        let schedule = sched.schedule(&inst, &mut rng.fork(7));
        let mode = match sched.name() {
            "happy-computation" => ConstraintMode::HAPPY_COMPUTATION,
            "happy-communication" => ConstraintMode::HAPPY_COMMUNICATION,
            _ => ConstraintMode::STRICT,
        };
        validate_schedule(&inst, &schedule, mode)
            .unwrap_or_else(|e| panic!("{}: {e}", sched.name()));
    }
}

#[test]
fn ilp_dominates_gus_on_paper_shaped_small_instances() {
    for seed in 0..5 {
        let scenario = ScenarioParams {
            workload: WorkloadParams { num_requests: 8, ..Default::default() },
            ..Default::default()
        };
        let mut rng = Rng::new(seed);
        let inst = build_instance(&scenario, &mut rng);
        let opt = BranchAndBound::default().solve(&inst);
        assert!(opt.exact, "seed {seed} must solve exactly");
        let gus = Gus::default().schedule(&inst, &mut rng);
        assert!(opt.schedule.objective() >= gus.objective() - 1e-9);
        validate_schedule(&inst, &opt.schedule, ConstraintMode::STRICT).unwrap();
    }
}

// ----------------------------------------------------------------- config

#[test]
fn config_file_drives_the_simulation() {
    let dir = std::env::temp_dir().join("edgeus_int_cfg");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("scenario.json");
    std::fs::write(
        &path,
        r#"{
          "topology": {"num_edge": 4, "num_cloud": 1},
          "catalog": {"num_services": 8, "num_tiers": 3},
          "workload": {"num_requests": 25, "accuracy_mean_pct": 40},
          "runs": 6, "seed": 123, "threads": 2
        }"#,
    )
    .unwrap();
    let mc = config::load_montecarlo(path.to_str().unwrap()).unwrap();
    assert_eq!(mc.runs, 6);
    let stats = mc.run();
    assert_eq!(stats.len(), 6);
    assert_eq!(stats[0].satisfied_pct.count(), 6);
}

#[test]
fn scenario_json_round_trip_preserves_behaviour() {
    let scenario = ScenarioParams::default();
    let json = config::scenario_to_json(&scenario).pretty();
    let parsed = config::scenario_from_json(&Json::parse(&json).unwrap());
    let a = build_instance(&scenario, &mut Rng::new(5));
    let b = build_instance(&parsed, &mut Rng::new(5));
    assert_eq!(a.num_requests(), b.num_requests());
    for (x, y) in a.requests.iter().zip(b.requests.iter()) {
        assert_eq!(x.min_accuracy_pct, y.min_accuracy_pct);
        assert_eq!(x.max_completion_ms, y.max_completion_ms);
    }
}
