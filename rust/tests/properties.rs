//! Property-based tests over the coordinator invariants, using the
//! in-tree `prop` harness (see `util::prop`) on randomized instances.

use edgeus::coordinator::us::{
    qos_satisfied, user_satisfaction, validate_schedule, ConstraintMode,
};
use edgeus::model::service::CatalogParams;
use edgeus::model::topology::TopologyParams;
use edgeus::prelude::*;
use edgeus::util::prop::{self, Gen};
use edgeus::workload::WorkloadParams;

/// Draw a random-but-valid scenario from the generator.
fn random_instance(g: &mut Gen) -> ProblemInstance<'static> {
    let scenario = ScenarioParams {
        topology: TopologyParams {
            num_edge: g.usize_in(1..8),
            num_cloud: g.usize_in(1..3),
            ..Default::default()
        },
        catalog: CatalogParams {
            num_services: g.usize_in(1..12),
            num_tiers: g.usize_in(1..6),
            ..Default::default()
        },
        workload: WorkloadParams {
            num_requests: g.usize_in(1..60),
            accuracy_mean_pct: g.f64_in(20.0..80.0),
            deadline_mean_ms: g.f64_in(500.0..8000.0),
            queue_delay_max_ms: g.f64_in(0.0..500.0),
            ..Default::default()
        },
    };
    let seed = g.u64_in(0..u64::MAX / 2);
    let inst = build_instance(&scenario, &mut Rng::new(seed));
    inst.validate().expect("generated instance must be valid");
    inst
}

#[test]
fn prop_every_policy_respects_its_constraint_mode() {
    prop::check(60, |g| {
        let inst = random_instance(g);
        let seed = g.u64_in(0..1 << 40);
        for sched in all_schedulers() {
            let schedule = sched.schedule(&inst, &mut Rng::new(seed));
            let mode = match sched.name() {
                "happy-computation" => ConstraintMode::HAPPY_COMPUTATION,
                "happy-communication" => ConstraintMode::HAPPY_COMMUNICATION,
                _ => ConstraintMode::STRICT,
            };
            validate_schedule(&inst, &schedule, mode)
                .unwrap_or_else(|e| panic!("{}: {e}", sched.name()));
        }
    });
}

#[test]
fn prop_at_most_one_assignment_per_request() {
    // Constraint (2a) is structural in `Schedule`, but verify the slots
    // map requests one-to-one and never duplicate a request id.
    prop::check(40, |g| {
        let inst = random_instance(g);
        let s = Gus::default().schedule(&inst, &mut Rng::new(1));
        assert_eq!(s.slots.len(), inst.num_requests());
        for (i, slot) in s.slots.iter().enumerate() {
            if let Some(a) = slot {
                assert_eq!(a.request.0, i);
            }
        }
    });
}

#[test]
fn prop_gus_assignments_always_meet_qos_and_positive_us() {
    prop::check(60, |g| {
        let inst = random_instance(g);
        let s = Gus::default().schedule(&inst, &mut Rng::new(2));
        for a in s.slots.iter().flatten() {
            let req = &inst.requests[a.request.0];
            assert!(qos_satisfied(req, &a.candidate));
            assert!(a.us >= 0.0, "strict-mode US must be non-negative");
            let expect = user_satisfaction(
                req,
                &a.candidate,
                inst.max_accuracy_pct,
                inst.max_completion_ms,
            );
            assert!((a.us - expect).abs() < 1e-9, "cached US must be exact");
        }
    });
}

#[test]
fn prop_objective_is_mean_of_assigned_us() {
    prop::check(40, |g| {
        let inst = random_instance(g);
        let s = Gus::default().schedule(&inst, &mut Rng::new(3));
        let manual: f64 = s.slots.iter().flatten().map(|a| a.us).sum::<f64>()
            / inst.num_requests().max(1) as f64;
        assert!((s.objective() - manual).abs() < 1e-12);
    });
}

#[test]
fn prop_decision_mix_sums_to_100() {
    prop::check(40, |g| {
        let inst = random_instance(g);
        let seed = g.u64_in(0..1 << 40);
        for sched in all_schedulers() {
            let s = sched.schedule(&inst, &mut Rng::new(seed));
            let mix = s.decision_mix_pct(&inst);
            let sum: f64 = mix.iter().sum();
            assert!((sum - 100.0).abs() < 1e-6, "{}: {mix:?}", sched.name());
        }
    });
}

#[test]
fn prop_bb_optimum_dominates_every_heuristic() {
    prop::check(25, |g| {
        // Keep instances small enough for exact solves.
        let scenario = ScenarioParams {
            topology: TopologyParams {
                num_edge: g.usize_in(1..4),
                num_cloud: 1,
                ..Default::default()
            },
            catalog: CatalogParams {
                num_services: g.usize_in(1..4),
                num_tiers: g.usize_in(1..4),
                ..Default::default()
            },
            workload: WorkloadParams {
                num_requests: g.usize_in(1..9),
                ..Default::default()
            },
        };
        let inst = build_instance(&scenario, &mut Rng::new(g.u64_in(0..1 << 40)));
        let opt = BranchAndBound::default().solve(&inst);
        assert!(opt.exact);
        for sched in all_schedulers() {
            if sched.name().starts_with("happy") {
                continue; // relaxed constraints: not comparable
            }
            let s = sched.schedule(&inst, &mut Rng::new(4));
            assert!(
                opt.schedule.objective() >= s.objective() - 1e-9,
                "{} beat the exact optimum",
                sched.name()
            );
        }
    });
}

#[test]
fn prop_relaxing_constraints_never_reduces_served_count() {
    prop::check(40, |g| {
        let inst = random_instance(g);
        let strict = Gus::default().schedule(&inst, &mut Rng::new(5));
        let hc = Gus::with_mode(ConstraintMode::HAPPY_COMPUTATION)
            .schedule(&inst, &mut Rng::new(5));
        let hm = Gus::with_mode(ConstraintMode::HAPPY_COMMUNICATION)
            .schedule(&inst, &mut Rng::new(5));
        assert!(hc.served() >= strict.served());
        assert!(hm.served() >= strict.served());
    });
}

#[test]
fn prop_capacity_never_oversubscribed_by_construction() {
    prop::check(40, |g| {
        let inst = random_instance(g);
        let s = Gus::default().schedule(&inst, &mut Rng::new(6));
        let mut gamma = vec![0.0; inst.num_servers()];
        let mut eta = vec![0.0; inst.num_servers()];
        for a in s.slots.iter().flatten() {
            gamma[a.candidate.server.0] += a.candidate.comp_cost;
            if a.candidate.offloaded {
                eta[inst.requests[a.request.0].covering.0] += a.candidate.comm_cost;
            }
        }
        for j in 0..inst.num_servers() {
            assert!(gamma[j] <= inst.topology.servers[j].gamma + 1e-9);
            assert!(eta[j] <= inst.topology.servers[j].eta + 1e-9);
        }
    });
}

#[test]
fn prop_tightening_deadline_never_helps() {
    // Monotonicity: shrinking every C_i can only reduce GUS satisfaction.
    prop::check(30, |g| {
        let mut inst = random_instance(g);
        let loose = Gus::default().schedule(&inst, &mut Rng::new(7));
        for r in &mut inst.requests {
            r.max_completion_ms *= 0.5;
        }
        let tight = Gus::default().schedule(&inst, &mut Rng::new(7));
        assert!(tight.satisfied(&inst) <= loose.served());
    });
}

#[test]
fn prop_flash_crowd_never_oversubscribes_capacity_and_conserves_requests() {
    use edgeus::scenario::Script;
    use edgeus::sim::{Des, DesConfig};
    // DES invariants under the flash-crowd surge, across random seeds and
    // offered loads: the committed in-service work never exceeds the live
    // γ (schedulers only commit against the frame residual), and the
    // report's conservation invariants hold at every decision boundary.
    prop::check(8, |g| {
        let horizon_ms = 30_000.0;
        let mut cfg = DesConfig {
            scenario: ScenarioParams {
                topology: TopologyParams { num_edge: 3, num_cloud: 1, ..Default::default() },
                catalog: CatalogParams { num_services: 8, num_tiers: 3, ..Default::default() },
                workload: WorkloadParams::default(),
            },
            horizon_ms,
            arrival_rate_per_s: g.f64_in(4.0..40.0),
            seed: g.u64_in(0..1 << 32),
            ..Default::default()
        };
        cfg.script = Script::builtin("flash-crowd", horizon_ms, cfg.scenario.topology.num_edge);
        assert!(cfg.script.is_some(), "flash-crowd is a builtin");
        let gus = Gus::default();
        let report = Des::new(cfg, &gus).run();
        report.check_conservation().unwrap();
        // flash-crowd scripts no outages, so live γ never shrinks and
        // utilization > 1 would mean a genuine capacity overdraw.
        for (k, f) in report.frames.iter().enumerate() {
            assert!(
                f.capacity_utilization <= 1.0 + 1e-9,
                "frame {k}: committed busy exceeds live γ ({})",
                f.capacity_utilization
            );
        }
    });
}

#[test]
fn prop_schedule_deterministic_for_deterministic_policies() {
    prop::check(25, |g| {
        let inst = random_instance(g);
        for name in ["gus", "offload-all", "local-all"] {
            let p = scheduler_by_name(name).unwrap();
            let a = p.schedule(&inst, &mut Rng::new(1));
            let b = p.schedule(&inst, &mut Rng::new(2));
            let key = |s: &Schedule| {
                s.slots
                    .iter()
                    .map(|x| x.as_ref().map(|a| (a.candidate.server.0, a.candidate.tier.0)))
                    .collect::<Vec<_>>()
            };
            assert_eq!(key(&a), key(&b), "{name} must ignore the RNG");
        }
    });
}
