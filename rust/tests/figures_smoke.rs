//! Smoke tests for the figure-regeneration harness: every panel runs at
//! reduced scale and reproduces the paper's qualitative trends.

use edgeus::figures::{
    run_numerical_sweep, run_optimal_gap, NumericalConfig, NumericalFigure,
};

fn gus_series(series: &edgeus::metrics::Series) -> &Vec<f64> {
    &series.policies.iter().find(|(n, _, _)| n == "gus").unwrap().1
}

#[test]
fn fig1a_more_delay_budget_helps() {
    let cfg = NumericalConfig::quick();
    let s = run_numerical_sweep(NumericalFigure::Fig1a, &cfg, &[500.0, 3000.0, 8000.0]);
    let gus = gus_series(&s);
    assert!(gus[2] > gus[0], "{gus:?}");
}

#[test]
fn fig1b_higher_accuracy_demand_hurts() {
    let cfg = NumericalConfig::quick();
    let s = run_numerical_sweep(NumericalFigure::Fig1b, &cfg, &[30.0, 60.0, 85.0]);
    let gus = gus_series(&s);
    assert!(gus[2] < gus[0], "{gus:?}");
}

#[test]
fn fig1c_load_hurts() {
    let cfg = NumericalConfig::quick();
    let s = run_numerical_sweep(NumericalFigure::Fig1c, &cfg, &[20.0, 120.0]);
    let gus = gus_series(&s);
    assert!(gus[1] < gus[0], "{gus:?}");
}

#[test]
fn fig1d_queue_delay_hurts() {
    let cfg = NumericalConfig::quick();
    let s = run_numerical_sweep(NumericalFigure::Fig1d, &cfg, &[0.0, 2000.0]);
    let gus = gus_series(&s);
    assert!(gus[1] < gus[0], "{gus:?}");
}

#[test]
fn gus_dominates_baselines_across_panels() {
    let cfg = NumericalConfig::quick();
    for fig in [NumericalFigure::Fig1a, NumericalFigure::Fig1c] {
        let sweep = [fig.default_sweep()[0], *fig.default_sweep().last().unwrap()];
        let s = run_numerical_sweep(fig, &cfg, &sweep);
        let gus = gus_series(&s).clone();
        for baseline in ["random", "offload-all", "local-all"] {
            let b = &s.policies.iter().find(|(n, _, _)| n == baseline).unwrap().1;
            for (i, (g, b)) in gus.iter().zip(b.iter()).enumerate() {
                assert!(
                    g + 1e-9 >= *b,
                    "{}: GUS {g:.1} < {baseline} {b:.1} at point {i}",
                    fig.id()
                );
            }
        }
    }
}

#[test]
fn optimal_gap_matches_paper_band() {
    let r = run_optimal_gap(&[4, 6], 6, 17);
    assert!(r.exact_fraction == 1.0, "small sizes must solve exactly");
    assert!(
        r.mean_ratio >= 0.85 && r.mean_ratio <= 1.0,
        "paper reports ~0.90, got {:.3}",
        r.mean_ratio
    );
}

#[test]
fn series_emitters_work_for_real_output() {
    let cfg = NumericalConfig::quick();
    let s = run_numerical_sweep(NumericalFigure::Fig1a, &cfg, &[1000.0, 4000.0]);
    let md = s.to_markdown();
    assert!(md.contains("gus"));
    let csv = s.to_csv();
    assert_eq!(csv.lines().count(), 3);
    let json = s.to_json().pretty();
    assert!(edgeus::util::json::Json::parse(&json).is_ok());
}
