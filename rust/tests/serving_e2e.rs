//! End-to-end serving tests: the full L3 → PJRT → EdgeNet path, run live
//! at high time compression. Skipped (with a notice) when `artifacts/`
//! has not been built.

use edgeus::serving::{ServingConfig, ServingSystem};

fn config(requests: usize, scheduler: &str) -> Option<ServingConfig> {
    let mut cfg = ServingConfig::default();
    if !std::path::Path::new(&format!("{}/manifest.json", cfg.artifacts_dir)).exists() {
        eprintln!("SKIP: run `make artifacts` first");
        return None;
    }
    cfg.scheduler = scheduler.into();
    cfg.total_requests = requests;
    cfg.window_ms = 30_000.0;
    cfg.time_scale = 100.0;
    cfg.seed = 13;
    Some(cfg)
}

#[test]
fn every_request_is_accounted_for() {
    let Some(cfg) = config(40, "gus") else { return };
    let m = ServingSystem::new(cfg).unwrap().run().unwrap();
    assert_eq!(m.total_requests, 40);
    assert_eq!(m.served + m.dropped, 40, "served {} + dropped {}", m.served, m.dropped);
    assert_eq!(m.served, m.local + m.offload_cloud + m.offload_peer);
    assert!(m.satisfied <= m.served);
}

#[test]
fn gus_satisfies_most_users_at_light_load() {
    let Some(cfg) = config(30, "gus") else { return };
    let m = ServingSystem::new(cfg).unwrap().run().unwrap();
    assert!(
        m.satisfied_pct() >= 80.0,
        "light load should be nearly all satisfied, got {:.1}%",
        m.satisfied_pct()
    );
    // Real inference happened.
    assert!(m.inference.count() > 0);
    assert!(m.inference.mean() > 0.0);
}

#[test]
fn local_all_never_offloads_and_offload_all_never_serves_locally() {
    let Some(cfg) = config(30, "local-all") else { return };
    let m = ServingSystem::new(cfg).unwrap().run().unwrap();
    assert_eq!(m.offload_cloud + m.offload_peer, 0, "local-all must not offload");

    let Some(cfg) = config(30, "offload-all") else { return };
    let m = ServingSystem::new(cfg).unwrap().run().unwrap();
    assert_eq!(m.local, 0, "offload-all must not serve locally");
    assert_eq!(m.offload_peer, 0, "offload-all targets the cloud only");
}

#[test]
fn unknown_scheduler_is_rejected() {
    let Some(mut cfg) = config(5, "gus") else { return };
    cfg.scheduler = "not-a-policy".into();
    assert!(ServingSystem::new(cfg).unwrap().run().is_err());
}

#[test]
fn unknown_tier_is_rejected_at_construction() {
    let Some(mut cfg) = config(5, "gus") else { return };
    cfg.edge_tiers = vec!["hallucinated".into()];
    assert!(ServingSystem::new(cfg).is_err());
}

#[test]
fn congestion_degrades_local_all_more_than_gus() {
    // The core of Fig. 1(e): under pressure the greedy mix beats
    // forced-local. One seed, both policies, same workload.
    let Some(mut gus_cfg) = config(150, "gus") else { return };
    gus_cfg.window_ms = 20_000.0;
    let Some(mut local_cfg) = config(150, "local-all") else { return };
    local_cfg.window_ms = 20_000.0;
    let gus = ServingSystem::new(gus_cfg).unwrap().run().unwrap();
    let local = ServingSystem::new(local_cfg).unwrap().run().unwrap();
    assert!(
        gus.satisfied_pct() > local.satisfied_pct(),
        "gus {:.1}% ≤ local-all {:.1}% under congestion",
        gus.satisfied_pct(),
        local.satisfied_pct()
    );
}
