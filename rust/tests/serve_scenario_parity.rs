//! DES ↔ serving parity and live-path property tests for scenario
//! injection: the same world + script driven through the discrete-event
//! simulator and through the live serving runtime (synthetic inference,
//! high time compression) must tell the same story — satisfaction and
//! the drop-reason mix agree within tolerance, request conservation
//! holds under every built-in scenario, and the live path never
//! dispatches to a down server or overcommits a node past its γ.
//!
//! Tolerances are sized analytically, not fitted: the two paths share
//! the frame cadence (3 s), admission-queue capacity (4), QoS
//! thresholds (A ≥ 50%, C ≤ 5300 ms) and arrival rate (2/s), but differ
//! structurally — the DES draws per-class γ/η defaults while serving
//! pins γ = 3/3/8, the DES generates a Poisson-count workload while
//! serving emits exactly `total_requests`, and transfers take wall time
//! live vs. a comm-matrix lookup in the DES. The bands below are wide
//! enough to absorb those differences and tight enough to catch the
//! regressions this harness exists for: misclassified drop reasons,
//! conservation leaks, and scripted events that the live path ignores.

use std::sync::{Arc, Mutex};

use edgeus::coordinator::gus::Gus;
use edgeus::model::service::CatalogParams;
use edgeus::model::topology::TopologyParams;
use edgeus::obs::DropReason;
use edgeus::scenario::Script;
use edgeus::serving::{FrameProbe, ServingConfig, ServingSystem};
use edgeus::sim::{Des, DesConfig, DesReport};
use edgeus::workload::{ScenarioParams, WorkloadParams};

const SEEDS: [u64; 3] = [7, 11, 23];

/// Synthetic serving world: the default paper testbed (2 edges + cloud,
/// 120 requests over 60 s) with mock inference so the suite runs
/// without compiled artifacts.
fn serve_cfg(script: Option<Script>, seed: u64, time_scale: f64) -> ServingConfig {
    ServingConfig { synthetic: true, script, seed, time_scale, ..ServingConfig::default() }
}

/// The DES view of the same world: 2 edges + 1 cloud, one service whose
/// 3-tier ladder matches the serving calibration (1300 ms edge / 300 ms
/// cloud base, ×1.10 per tier, accuracies spanning the synthetic
/// manifest's 40–63% band), fixed QoS at the serving thresholds, and
/// the same 2 req/s over a 60 s horizon.
fn des_mirror(script_name: &str, seed: u64) -> DesReport {
    let cfg = DesConfig {
        scenario: ScenarioParams {
            topology: TopologyParams { num_edge: 2, num_cloud: 1, ..Default::default() },
            catalog: CatalogParams {
                num_services: 1,
                num_tiers: 3,
                edge_proc_lo_ms: 1_300.0,
                edge_proc_hi_ms: 1_300.0,
                cloud_proc_ms: 300.0,
                accuracy_lo_pct: 40.0,
                accuracy_hi_pct: 63.0,
                tier_slowdown: 1.10,
                ..Default::default()
            },
            workload: WorkloadParams {
                accuracy_mean_pct: 50.0,
                accuracy_std_pct: 0.0,
                deadline_mean_ms: 5_300.0,
                deadline_std_ms: 0.0,
                ..Default::default()
            },
        },
        horizon_ms: 60_000.0,
        arrival_rate_per_s: 2.0,
        script: Some(Script::builtin(script_name, 60_000.0, 2).unwrap()),
        seed,
        ..Default::default()
    };
    Des::new(cfg, &Gus::default()).run()
}

fn mean(xs: &[f64]) -> f64 {
    xs.iter().sum::<f64>() / xs.len() as f64
}

// ------------------------------------------------------------ conservation

#[test]
fn every_builtin_scenario_conserves_requests_across_seeds() {
    for name in Script::builtin_names() {
        for seed in SEEDS {
            let script = Script::builtin(name, 60_000.0, 2).unwrap();
            let m = ServingSystem::new(serve_cfg(Some(script), seed, 400.0))
                .unwrap()
                .run()
                .unwrap_or_else(|e| panic!("{name} seed {seed}: {e}"));
            // `run()` already enforces conservation; re-check through the
            // public API so a future relaxation there cannot slip by.
            m.check_conservation().unwrap_or_else(|e| panic!("{name} seed {seed}: {e}"));
            assert_eq!(m.total_requests, 120, "{name} seed {seed}");
            assert!(
                !m.phases.is_empty(),
                "{name} seed {seed}: scripted run must report scenario phases"
            );
            assert!(
                m.phases.len() >= 2,
                "{name} seed {seed}: expected the start phase plus at least one event phase"
            );
            assert_eq!(m.phases[0].label, "start", "{name} seed {seed}");
            assert_eq!(m.phases[0].from_ms, 0.0, "{name} seed {seed}");
            // Phase boundaries must be the applied events, in order.
            for w in m.phases.windows(2) {
                assert!(
                    w[0].from_ms < w[1].from_ms,
                    "{name} seed {seed}: phase boundaries must be strictly increasing"
                );
            }
        }
    }
}

#[test]
fn unscripted_synthetic_run_reports_no_phases() {
    let mut cfg = serve_cfg(None, 7, 400.0);
    cfg.total_requests = 40;
    cfg.window_ms = 20_000.0;
    let m = ServingSystem::new(cfg).unwrap().run().unwrap();
    m.check_conservation().unwrap();
    assert!(m.phases.is_empty(), "static-world runs have no scenario phases");
}

// ----------------------------------------------------------------- parity

#[test]
fn des_and_serving_agree_on_satisfaction_and_drop_mix() {
    // (script, satisfaction band in percentage points, queue-full band,
    // scheduler-drop band — both bands as fractions of the workload).
    let cases = [("edge-failover", 30.0, 0.20, 0.25), ("flash-crowd", 35.0, 0.30, 0.30)];
    for (name, sat_tol, qf_tol, sched_tol) in cases {
        let mut serve_sat = Vec::new();
        let mut serve_qf = Vec::new();
        let mut serve_sched = Vec::new();
        let mut des_sat = Vec::new();
        let mut des_qf = Vec::new();
        let mut des_sched = Vec::new();
        for seed in SEEDS {
            let script = Script::builtin(name, 60_000.0, 2).unwrap();
            let m = ServingSystem::new(serve_cfg(Some(script), seed, 200.0))
                .unwrap()
                .run()
                .unwrap_or_else(|e| panic!("{name} seed {seed}: {e}"));
            let total = m.total_requests as f64;
            serve_sat.push(m.satisfied_pct());
            serve_qf.push(m.drops(DropReason::QueueFull) as f64 / total);
            serve_sched.push((m.dropped - m.drops(DropReason::QueueFull)) as f64 / total);

            let r = des_mirror(name, seed);
            assert_eq!(r.generated, r.served + r.dropped + r.rejected_at_queue, "{name}");
            let gen = r.generated as f64;
            des_sat.push(r.satisfied_pct());
            des_qf.push(r.rejected_at_queue as f64 / gen);
            des_sched.push(r.dropped as f64 / gen);
        }
        let (ss, ds) = (mean(&serve_sat), mean(&des_sat));
        assert!(
            (ss - ds).abs() <= sat_tol,
            "{name}: satisfaction diverged — serving {ss:.1}% vs DES {ds:.1}% (tol {sat_tol})"
        );
        let (sq, dq) = (mean(&serve_qf), mean(&des_qf));
        assert!(
            (sq - dq).abs() <= qf_tol,
            "{name}: queue-full fraction diverged — serving {sq:.3} vs DES {dq:.3} (tol {qf_tol})"
        );
        let (sr, dr) = (mean(&serve_sched), mean(&des_sched));
        assert!(
            (sr - dr).abs() <= sched_tol,
            "{name}: scheduler-drop fraction diverged — serving {sr:.3} vs DES {dr:.3} \
             (tol {sched_tol})"
        );
        if name == "edge-failover" {
            // Light load with a cloud absorber: neither path may collapse.
            assert!(ss >= 35.0, "{name}: serving satisfaction collapsed to {ss:.1}%");
            assert!(ds >= 35.0, "{name}: DES satisfaction collapsed to {ds:.1}%");
        }
        if name == "flash-crowd" {
            // A ×8 burst against 4-slot admission queues must bounce
            // requests at the door on both paths.
            assert!(sq > 0.0, "{name}: serving saw no queue pressure under the burst");
            assert!(dq > 0.0, "{name}: DES saw no queue pressure under the burst");
        }
    }
}

// -------------------------------------------------------------- properties

#[test]
fn scripted_events_never_dispatch_to_down_servers_and_respect_gamma() {
    for name in ["edge-failover", "flash-crowd"] {
        let script = Script::builtin(name, 60_000.0, 2).unwrap();
        let probes: Arc<Mutex<Vec<FrameProbe>>> = Arc::new(Mutex::new(Vec::new()));
        let tap = Arc::clone(&probes);
        let m = ServingSystem::new(serve_cfg(Some(script), 7, 300.0))
            .unwrap()
            .with_probe(Arc::new(move |p: &FrameProbe| tap.lock().unwrap().push(p.clone())))
            .run()
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        m.check_conservation().unwrap();
        let probes = probes.lock().unwrap();
        assert!(!probes.is_empty(), "{name}: leader never probed a frame");
        assert!(
            probes.iter().any(|p| p.events_applied > 0),
            "{name}: the script was never applied to the live world"
        );
        for p in probes.iter() {
            assert_eq!(p.up.len(), 3, "{name}: 2 edges + cloud");
            assert_eq!(p.inflight.len(), 3, "{name}");
            assert_eq!(p.gamma.len(), 3, "{name}");
            // No frame may commit work to a server the scenario downed.
            for &s in &p.assigned_servers {
                assert!(
                    p.up[s],
                    "{name}: frame at {:.0} ms dispatched request(s) to down server {s}",
                    p.now_ms
                );
            }
            // Committed inflight (executing + reserved in transfer) stays
            // within the node's γ at every observed boundary.
            for (j, &inflight) in p.inflight.iter().enumerate() {
                assert!(
                    (inflight as f64) <= p.gamma[j],
                    "{name}: frame at {:.0} ms overcommitted server {j}: \
                     inflight {inflight} > γ {}",
                    p.now_ms,
                    p.gamma[j]
                );
            }
        }
        if name == "edge-failover" {
            // The builtin downs edge 1 over [18 s, 39 s) of the 60 s
            // window: the outage must be visible at some boundary and the
            // world must come back up afterwards.
            assert!(
                probes.iter().any(|p| !p.up[1]),
                "edge-failover: victim edge never observed down"
            );
            let last = probes.last().unwrap();
            assert!(
                last.up.iter().all(|&u| u),
                "edge-failover: world must be fully up after ServerUp"
            );
        }
    }
}
