//! End-to-end coverage for `edgeus verify` (DESIGN.md §Static-Analysis):
//!
//! * every diagnostic code in the table has one minimal failing fixture
//!   under `tests/fixtures/verify/` that triggers exactly that code;
//! * CLI exit semantics (errors → 1, warnings → 0, `--strict` → 1);
//! * `--json` output is byte-stable and identical to the library's
//!   rendering;
//! * the built-in scenario scripts and the shipped example worlds are
//!   accepted cleanly;
//! * the verify→simulate property: a config the verifier accepts runs
//!   the DES without conservation violations across seeds.

use edgeus::prelude::*;
use edgeus::verify::{verify_des_config, verify_file, Code, VerifyOptions};
use std::path::PathBuf;
use std::process::Command;

fn fixture(name: &str) -> String {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures/verify")
        .join(name)
        .to_string_lossy()
        .into_owned()
}

fn example_world(name: &str) -> String {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("../examples/worlds")
        .join(name)
        .to_string_lossy()
        .into_owned()
}

fn run_cli(args: &[&str]) -> std::process::Output {
    Command::new(env!("CARGO_BIN_EXE_edgeus"))
        .args(args)
        .output()
        .expect("spawn edgeus")
}

/// One fixture per code. `E019` (file unreadable) is the one entry whose
/// "fixture" is a path that intentionally does not exist.
const TABLE: &[(Code, &str)] = &[
    (Code::ServerIndex, "E001_server_index.json"),
    (Code::EdgeIndex, "E002_edge_index.json"),
    (Code::ServiceIndex, "E003_service_index.json"),
    (Code::TierIndex, "E004_tier_index.json"),
    (Code::EventTime, "E005_event_time.json"),
    (Code::DownWhileDown, "E006_down_while_down.json"),
    (Code::UpWhileUp, "E007_up_while_up.json"),
    (Code::LinkPair, "E008_link_pair.json"),
    (Code::Mobility, "E009_mobility.json"),
    (Code::LoadBurst, "E010_load_burst.json"),
    (Code::UnknownEvent, "E011_unknown_event.json"),
    (Code::UnknownField, "E012_unknown_field.json"),
    (Code::NoEdges, "E013_no_edges.json"),
    (Code::BadParam, "E014_bad_param.json"),
    (Code::InvertedBand, "E015_inverted_band.json"),
    (Code::DuplicateAssignment, "E016_duplicate_assignment.json"),
    (Code::DownServerAssignment, "E017_down_server_assignment.json"),
    (Code::GammaOverflow, "E018_gamma_overflow.json"),
    (Code::FileUnreadable, "E019_intentionally_missing.json"),
    (Code::ParseError, "E020_parse_error.json"),
    (Code::DemandExceedsCapacity, "W101_demand_exceeds_capacity.json"),
    (Code::ZeroGamma, "W102_zero_gamma.json"),
    (Code::DeadlineInfeasible, "W103_deadline_infeasible.json"),
    (Code::EventBeyondHorizon, "W104_event_beyond_horizon.json"),
    (Code::PermanentOutage, "W105_permanent_outage.json"),
    (Code::EmptyScript, "I201_empty_script.json"),
];

fn opts_for(code: Code) -> VerifyOptions {
    // The beyond-horizon check only fires when a horizon is known.
    if code == Code::EventBeyondHorizon {
        VerifyOptions { horizon_ms: Some(60_000.0), ..Default::default() }
    } else {
        VerifyOptions::default()
    }
}

#[test]
fn every_code_has_a_fixture_that_triggers_it() {
    assert_eq!(TABLE.len(), Code::ALL.len(), "table must cover the code table");
    for (i, code) in Code::ALL.iter().enumerate() {
        assert_eq!(TABLE[i].0, *code, "table order must match Code::ALL");
    }
    for (code, file) in TABLE {
        let d = verify_file(&fixture(file), &opts_for(*code));
        assert!(
            d.has_code(*code),
            "{file} must trigger {}; got:\n{}",
            code.as_str(),
            d.render_text()
        );
    }
}

#[test]
fn warning_and_info_fixtures_carry_no_errors() {
    for (code, file) in TABLE {
        if code.severity() == Severity::Error {
            continue;
        }
        let d = verify_file(&fixture(file), &opts_for(*code));
        assert!(!d.has_errors(), "{file} should be error-free:\n{}", d.render_text());
    }
}

#[test]
fn cli_exit_codes_follow_severity() {
    let e001 = fixture("E001_server_index.json");
    let err = run_cli(&["verify", e001.as_str()]);
    assert_eq!(err.status.code(), Some(1));
    assert!(String::from_utf8_lossy(&err.stdout).contains("E001"));

    let w105 = fixture("W105_permanent_outage.json");
    let warn = run_cli(&["verify", w105.as_str()]);
    assert_eq!(warn.status.code(), Some(0), "warnings alone must not fail");
    assert!(String::from_utf8_lossy(&warn.stdout).contains("W105"));

    let strict = run_cli(&["verify", w105.as_str(), "--strict"]);
    assert_eq!(strict.status.code(), Some(1), "--strict promotes warnings");
}

#[test]
fn json_output_is_byte_stable_and_matches_library() {
    let path = fixture("E016_duplicate_assignment.json");
    let a = run_cli(&["verify", path.as_str(), "--json"]);
    let b = run_cli(&["verify", path.as_str(), "--json"]);
    assert_eq!(a.stdout, b.stdout, "two runs must render identical bytes");
    let expected = format!(
        "{}\n",
        verify_file(&path, &VerifyOptions::default()).to_json().pretty()
    );
    assert_eq!(String::from_utf8_lossy(&a.stdout), expected);
    assert_eq!(a.status.code(), Some(1));
}

#[test]
fn builtin_scenarios_are_accepted() {
    let dir = std::env::temp_dir().join("edgeus_verify_cli_builtin");
    std::fs::create_dir_all(&dir).unwrap();
    for name in Script::builtin_names() {
        let s = Script::builtin(name, 120_000.0, 9).unwrap();
        let path = dir.join(format!("{name}.json"));
        s.save(path.to_str().unwrap()).unwrap();
        let out = run_cli(&["verify", path.to_str().unwrap(), "--horizon-s", "120"]);
        assert_eq!(
            out.status.code(),
            Some(0),
            "{name} must verify cleanly:\n{}",
            String::from_utf8_lossy(&out.stdout)
        );
    }
}

#[test]
fn shipped_example_worlds_are_accepted() {
    for world in ["paper-default.json", "small-campus.json"] {
        let path = example_world(world);
        let out = run_cli(&["verify", path.as_str()]);
        assert_eq!(
            out.status.code(),
            Some(0),
            "{world} must verify cleanly:\n{}{}",
            String::from_utf8_lossy(&out.stdout),
            String::from_utf8_lossy(&out.stderr)
        );
        assert!(String::from_utf8_lossy(&out.stdout).contains("OK"));
    }
}

#[test]
fn scenario_with_missing_script_exits_with_e019() {
    let out = run_cli(&["scenario", "--script", "/nonexistent/edgeus-nope.json"]);
    assert_ne!(out.status.code(), Some(0));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("E019"), "stderr was: {stderr}");
}

#[test]
fn scenario_with_malformed_script_exits_with_e020() {
    let bad = fixture("E020_parse_error.json");
    let out = run_cli(&["scenario", "--script", bad.as_str()]);
    assert_ne!(out.status.code(), Some(0));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("E020"), "stderr was: {stderr}");
}

/// The serve-side input gate: a script that verifies cleanly against the
/// paper's 10-server world but references servers outside the serving
/// config's 3-server world (2 edges + cloud) must be rejected before any
/// thread spawns, with the E-code *and* the byte offset of the offending
/// event in the source text.
#[test]
fn serve_rejects_out_of_world_scripts_with_byte_offsets() {
    // (fixture, expected code, expect a byte offset in the rendering)
    let cases = [
        ("E001_serving_script_server.json", "E001", true),
        ("E020_parse_error.json", "E020", false),
    ];
    for (file, code, wants_offset) in cases {
        let path = fixture(file);
        let out = run_cli(&["serve", "--synthetic", "--script", path.as_str()]);
        assert_eq!(
            out.status.code(),
            Some(1),
            "{file}: serve must refuse a bad script\nstdout: {}\nstderr: {}",
            String::from_utf8_lossy(&out.stdout),
            String::from_utf8_lossy(&out.stderr)
        );
        let stderr = String::from_utf8_lossy(&out.stderr);
        assert!(stderr.contains(code), "{file}: stderr was: {stderr}");
        if wants_offset {
            assert!(stderr.contains("byte"), "{file}: no byte offset in: {stderr}");
        }
    }
    // The same fixture is a *valid* script for the paper's world shape.
    let d = verify_file(
        &fixture("E001_serving_script_server.json"),
        &VerifyOptions::default(),
    );
    assert!(!d.has_errors(), "fixture must be paper-world-clean:\n{}", d.render_text());

    let out = run_cli(&["serve", "--synthetic", "--script", "/nonexistent/edgeus-nope.json"]);
    assert_ne!(out.status.code(), Some(0));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("E019"), "stderr was: {stderr}");
}

#[test]
fn serve_refuses_scenario_and_script_together() {
    let path = fixture("E001_serving_script_server.json");
    let out = run_cli(&[
        "serve",
        "--synthetic",
        "--scenario",
        "edge-failover",
        "--script",
        path.as_str(),
    ]);
    assert_ne!(out.status.code(), Some(0), "--scenario and --script are exclusive");
}

/// The property the verifier promises: anything it accepts simulates
/// without conservation violations.
#[test]
fn verify_accepted_configs_conserve_requests_across_seeds() {
    let defaults = DesConfig::default();
    let small = ScenarioParams {
        topology: crate_topology(3, 1),
        catalog: crate_catalog(10, 4),
        ..ScenarioParams::default()
    };
    let configs = vec![
        DesConfig { horizon_ms: 12_000.0, arrival_rate_per_s: 4.0, ..defaults.clone() },
        DesConfig {
            horizon_ms: 12_000.0,
            arrival_rate_per_s: 4.0,
            scenario: small,
            ..defaults.clone()
        },
        DesConfig {
            horizon_ms: 12_000.0,
            arrival_rate_per_s: 4.0,
            script: Script::builtin("edge-failover", 12_000.0, defaults.scenario.topology.num_edge),
            ..defaults
        },
    ];
    for (ci, base) in configs.into_iter().enumerate() {
        let d = verify_des_config(&base, &[]);
        assert!(d.is_empty(), "config {ci} must be verify-clean:\n{}", d.render_text());
        for seed in [1u64, 2, 3] {
            let mut cfg = base.clone();
            cfg.seed = seed;
            let policy = edgeus::coordinator::scheduler_by_name("gus").unwrap();
            let report = Des::new(cfg, policy.as_ref()).run();
            report
                .check_conservation()
                .unwrap_or_else(|e| panic!("config {ci} seed {seed}: {e}"));
        }
    }
}

fn crate_topology(num_edge: usize, num_cloud: usize) -> edgeus::model::topology::TopologyParams {
    edgeus::model::topology::TopologyParams { num_edge, num_cloud, ..Default::default() }
}

fn crate_catalog(num_services: usize, num_tiers: usize) -> edgeus::model::service::CatalogParams {
    edgeus::model::service::CatalogParams { num_services, num_tiers, ..Default::default() }
}
