//! Integration tests for the observability layer: an instrumented DES run
//! exports a parseable Chrome trace and Prometheus text whose counters
//! agree with the report; a disabled recorder leaves the report
//! byte-identical to an uninstrumented run; scenario world events show up
//! as trace markers.

use edgeus::coordinator::gus::Gus;
use edgeus::model::service::CatalogParams;
use edgeus::model::topology::TopologyParams;
use edgeus::obs::{chrome_trace, prometheus, DropReason, Recorder};
use edgeus::scenario::{EventKind, Script, ScriptedEvent};
use edgeus::sim::{Des, DesConfig};
use edgeus::util::json::Json;
use edgeus::workload::{ScenarioParams, WorkloadParams};

/// Small but non-trivial world: enough load that drops occur, short
/// enough that the suite stays fast.
fn cfg(rate: f64) -> DesConfig {
    DesConfig {
        scenario: ScenarioParams {
            topology: TopologyParams { num_edge: 3, num_cloud: 1, ..Default::default() },
            catalog: CatalogParams { num_services: 8, num_tiers: 3, ..Default::default() },
            workload: WorkloadParams::default(),
        },
        horizon_ms: 20_000.0,
        arrival_rate_per_s: rate,
        ..Default::default()
    }
}

#[test]
fn chrome_trace_round_trips_and_counts_requests() {
    let gus = Gus::default();
    let recorder = Recorder::enabled(1 << 14);
    let report = Des::new(cfg(30.0), &gus).with_recorder(&recorder).run();

    let trace = chrome_trace(&recorder);
    let dump = trace.dump();
    let parsed = Json::parse(&dump).expect("chrome trace must be valid JSON");
    assert_eq!(parsed.dump(), dump, "round-trip through the in-tree parser");
    let events = parsed.get("traceEvents").as_arr().expect("traceEvents array");
    assert!(events.len() > 2, "expected events beyond process metadata");
    // Every event carries the Chrome trace-event required keys.
    for e in events {
        assert!(e.get("ph").as_str().is_some(), "event missing ph: {e:?}");
        assert!(e.get("pid").as_f64().is_some(), "event missing pid: {e:?}");
    }
    // Counters in the recorder agree with the report's totals.
    assert_eq!(
        recorder.counter_value("edgeus_des_generated_total", "", "") as u64,
        report.generated
    );
    assert_eq!(
        recorder.counter_value("edgeus_des_served_total", "", "") as u64,
        report.served
    );
}

#[test]
fn prometheus_export_carries_drop_reasons() {
    let gus = Gus::default();
    let recorder = Recorder::enabled(1 << 14);
    // Overload hard so scheduler drops are guaranteed.
    let report = Des::new(cfg(150.0), &gus).with_recorder(&recorder).run();
    assert!(report.dropped + report.rejected_at_queue > 0, "overload must drop");

    let text = prometheus(&recorder);
    assert!(text.contains("# TYPE edgeus_des_generated_total counter"));
    // All five reasons are pre-declared, so the labels are always present
    // (the CI smoke step greps for this).
    for reason in DropReason::ALL {
        assert!(
            text.contains(&format!("reason=\"{}\"", reason.as_str())),
            "missing reason {} in:\n{text}",
            reason.as_str()
        );
    }
    // The per-reason counters sum to the report's drop totals.
    let explained: u64 = DropReason::ALL
        .iter()
        .map(|r| {
            recorder.counter_value("edgeus_des_dropped_total", "reason", r.as_str()) as u64
        })
        .sum();
    assert_eq!(explained, report.dropped + report.rejected_at_queue);
}

#[test]
fn disabled_recorder_is_byte_identical_to_absent() {
    let gus = Gus::default();
    let plain = Des::new(cfg(30.0), &gus).run();
    let recorder = Recorder::disabled();
    let traced = Des::new(cfg(30.0), &gus).with_recorder(&recorder).run();
    assert_eq!(plain.to_json().dump(), traced.to_json().dump());
    assert_eq!(recorder.total_events(), 0);
    assert!(traced.explain.is_empty(), "explanations only with an enabled recorder");
}

#[test]
fn scenario_events_become_trace_markers() {
    let gus = Gus::default();
    let mut c = cfg(10.0);
    c.script = Some(Script::new(
        "obs-test",
        vec![
            ScriptedEvent { at_ms: 5_000.0, kind: EventKind::ServerDown { server: 0 } },
            ScriptedEvent { at_ms: 12_000.0, kind: EventKind::ServerUp { server: 0 } },
        ],
    ));
    let recorder = Recorder::enabled(1 << 14);
    let _ = Des::new(c, &gus).with_recorder(&recorder).run();
    let names: Vec<&str> = recorder
        .events()
        .iter()
        .filter(|e| e.cat == "scenario")
        .map(|e| e.name)
        .collect();
    assert_eq!(names, vec!["server_down", "server_up"]);
    assert_eq!(
        recorder.counter_value("edgeus_scenario_events_total", "kind", "server_down"),
        1.0
    );
}

#[test]
fn explanations_cover_every_decision_frame() {
    let gus = Gus::default();
    let recorder = Recorder::enabled(1 << 14);
    let report = Des::new(cfg(150.0), &gus).with_recorder(&recorder).run();
    assert_eq!(report.explain.len() as u64, report.decisions);
    let explained_drops: u64 = report.explain.iter().map(|f| f.total_drops()).sum();
    assert_eq!(explained_drops, report.dropped);
    let md = report.explain_markdown();
    assert!(md.contains("| t (ms) |"), "markdown table header:\n{md}");
    // The JSON report gains an "explain" array only when instrumented.
    let j = report.to_json().dump();
    assert!(j.contains("\"explain\""));
}
