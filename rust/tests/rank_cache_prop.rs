//! Invalidation-correctness property for the GUS rank cache, driven by
//! the builtin scenario scripts: step each script against a live world
//! with ONE persistent `SchedScratch` (so the cache survives across
//! frames and must invalidate itself), and after every decision boundary
//! check that
//!
//!   1. the cached schedule is bitwise identical to a fresh
//!      enumerate+sort (`gus-nocache`) schedule of the same instance, and
//!   2. every cached ranked class equals a ranking freshly recomputed
//!      from `ProblemInstance::candidates` — same candidates, same split
//!      delays (reconstituted completion times match bit for bit), keys
//!      sorted descending.
//!
//! Scripts mutate server up/down state, comm rows, and placements, so a
//! stale entry surviving any of those would fail here deterministically.

use edgeus::coordinator::gus::Gus;
use edgeus::coordinator::rank_cache::CachedCand;
use edgeus::coordinator::{Schedule, Scheduler};
use edgeus::model::request::Request;
use edgeus::model::server::{ServerClass, ServerId};
use edgeus::model::service::{CatalogParams, Placement, ServiceCatalog};
use edgeus::model::topology::{Topology, TopologyParams};
use edgeus::model::ProblemInstance;
use edgeus::scenario::{ScenarioEngine, Script};
use edgeus::util::rng::Rng;

const HORIZON_MS: f64 = 60_000.0;
const FRAME_MS: f64 = 3_000.0;
const NUM_EDGE: usize = 3;
const NUM_SERVICES: usize = 6;
const NUM_TIERS: usize = 3;

fn world(seed: u64) -> (Topology, ServiceCatalog, Placement) {
    let mut rng = Rng::new(seed);
    let topology = Topology::paper_default(
        &TopologyParams { num_edge: NUM_EDGE, num_cloud: 1, ..Default::default() },
        &mut rng,
    );
    let catalog = ServiceCatalog::synthetic(
        &CatalogParams { num_services: NUM_SERVICES, num_tiers: NUM_TIERS, ..Default::default() },
        &mut rng,
    );
    let classes: Vec<ServerClass> = topology.servers.iter().map(|s| s.class).collect();
    let placement = Placement::random(&catalog, &classes, &mut rng);
    (topology, catalog, placement)
}

/// One request per (edge, service) pair so every rank class the world can
/// produce is looked up — and therefore validated — each frame.
fn all_class_requests(edge_ids: &[ServerId], rng: &mut Rng) -> Vec<Request> {
    let mut out = Vec::new();
    for &e in edge_ids {
        for k in 0..NUM_SERVICES {
            out.push(
                Request::new(out.len(), k, e.0)
                    .with_qos(rng.uniform(30.0, 65.0), rng.uniform(1500.0, 9000.0))
                    .with_queue_delay(rng.uniform(0.0, 400.0)),
            );
        }
    }
    out
}

fn assert_schedules_identical(a: &Schedule, b: &Schedule, ctx: &str) {
    assert_eq!(a.slots.len(), b.slots.len(), "{ctx}: slot count");
    for (i, (sa, sb)) in a.slots.iter().zip(b.slots.iter()).enumerate() {
        match (sa, sb) {
            (None, None) => {}
            (Some(x), Some(y)) => {
                assert_eq!(x.request, y.request, "{ctx} slot {i}: request");
                assert_eq!(x.candidate.server, y.candidate.server, "{ctx} slot {i}: server");
                assert_eq!(x.candidate.tier, y.candidate.tier, "{ctx} slot {i}: tier");
                assert_eq!(
                    x.candidate.completion_ms.to_bits(),
                    y.candidate.completion_ms.to_bits(),
                    "{ctx} slot {i}: completion"
                );
                assert_eq!(x.us.to_bits(), y.us.to_bits(), "{ctx} slot {i}: us");
            }
            _ => panic!("{ctx} slot {i}: one path assigned, the other dropped"),
        }
    }
}

/// Recheck one cached class against a ranking recomputed from scratch via
/// the instance's own candidate enumeration.
fn assert_class_fresh(inst: &ProblemInstance, req_idx: usize, cached: &[CachedCand], ctx: &str) {
    let req = &inst.requests[req_idx];
    let fresh = inst.candidates(req_idx);
    assert_eq!(cached.len(), fresh.len(), "{ctx}: candidate count");

    // Keys must be ranked descending under the same total order the
    // cache sorts with (ties broken by enumeration index).
    for w in cached.windows(2) {
        let ord = w[0].rank_key.total_cmp(&w[1].rank_key);
        assert!(
            ord.is_gt() || (ord.is_eq() && w[0].orig < w[1].orig),
            "{ctx}: rank keys out of order"
        );
    }

    // Same multiset of candidates: realign by enumeration index and
    // compare every field, reconstituting completion from the split
    // delays exactly as the walk does.
    let mut by_orig: Vec<&CachedCand> = cached.iter().collect();
    by_orig.sort_by_key(|c| c.orig);
    for (cc, fc) in by_orig.iter().zip(fresh.iter()) {
        assert_eq!(cc.server, fc.server, "{ctx}: server");
        assert_eq!(cc.tier, fc.tier, "{ctx}: tier");
        assert_eq!(cc.offloaded, fc.offloaded, "{ctx}: offloaded");
        assert_eq!(cc.accuracy_pct.to_bits(), fc.accuracy_pct.to_bits(), "{ctx}: accuracy");
        assert_eq!(cc.comp_cost.to_bits(), fc.comp_cost.to_bits(), "{ctx}: comp_cost");
        assert_eq!(cc.comm_cost.to_bits(), fc.comm_cost.to_bits(), "{ctx}: comm_cost");
        assert_eq!(
            (req.queue_delay_ms + cc.comm_ms + cc.proc_ms).to_bits(),
            fc.completion_ms.to_bits(),
            "{ctx}: reconstituted completion"
        );
    }
}

#[test]
fn cached_ranking_survives_every_builtin_scenario() {
    let cached = Gus::default();
    let uncached = Gus::default().uncached();
    for (si, &name) in Script::builtin_names().iter().enumerate() {
        let (mut topology, catalog, mut placement) = world(0xA11CE + si as u64);
        let edge_ids = topology.edge_ids();
        let script = Script::builtin(name, HORIZON_MS, NUM_EDGE)
            .unwrap_or_else(|| panic!("unknown builtin {name}"));
        let mut engine = ScenarioEngine::new(script, &topology, NUM_SERVICES, NUM_TIERS);

        let mut scratch = edgeus::coordinator::SchedScratch::default();
        let mut schedule = Schedule::empty(0);
        let mut req_rng = Rng::new(0xF00D + si as u64);
        let mut sched_rng = Rng::new(1);
        let mut applied_total = 0u64;

        let mut now = 0.0;
        while now <= HORIZON_MS {
            applied_total += engine.advance(now, &mut topology, &mut placement);
            let requests = all_class_requests(&edge_ids, &mut req_rng);
            let inst = ProblemInstance::borrowed(&topology, &catalog, &placement, requests);
            let ctx = format!("{name} @ {now}ms");

            cached.schedule_into(&inst, &mut sched_rng, &mut scratch, &mut schedule);
            let fresh = uncached.schedule(&inst, &mut sched_rng);
            assert_schedules_identical(&schedule, &fresh, &ctx);

            for (i, req) in inst.requests.iter().enumerate() {
                let class = scratch
                    .rank_cache
                    .ranked_class(req.covering, req.service)
                    .unwrap_or_else(|| panic!("{ctx}: class ({req:?}) not built"));
                assert_class_fresh(&inst, i, class, &ctx);
            }
            now += FRAME_MS;
        }

        assert!(applied_total > 0, "{name}: no event ever applied — test is vacuous");
        assert!(scratch.rank_cache.hits > 0, "{name}: cache never hit");
        assert!(scratch.rank_cache.misses > 0, "{name}: cache never invalidated");
    }
}
