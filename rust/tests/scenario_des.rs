//! Integration tests for the dynamic scenario engine: each built-in
//! scenario runs end-to-end through the DES and GUS visibly reacts to
//! its events; same-seed runs are byte-identical (with and without a
//! script); scripts survive a JSON save → load → re-run round-trip.
//!
//! Phase comparisons use multi-seed means and guard bands (satisfaction
//! is counted at completion time, which lags arrival by up to a
//! deadline), with margins far below the injected effect sizes.

use edgeus::coordinator::gus::Gus;
use edgeus::model::service::CatalogParams;
use edgeus::model::topology::TopologyParams;
use edgeus::scenario::{EventKind, Script, ScriptedEvent};
use edgeus::sim::{Des, DesConfig, DesReport};
use edgeus::util::json::Json;
use edgeus::workload::{ScenarioParams, WorkloadParams};

/// 120 s world with a 10 × 4 catalog (small enough that every edge holds
/// every replica — placement is not the variable under test).
fn base_cfg(num_edge: usize, num_cloud: usize, rate: f64) -> DesConfig {
    DesConfig {
        scenario: ScenarioParams {
            topology: TopologyParams { num_edge, num_cloud, ..Default::default() },
            catalog: CatalogParams { num_services: 10, num_tiers: 4, ..Default::default() },
            workload: WorkloadParams {
                deadline_mean_ms: 4000.0,
                deadline_std_ms: 1000.0,
                ..Default::default()
            },
        },
        horizon_ms: 120_000.0,
        arrival_rate_per_s: rate,
        ..Default::default()
    }
}

fn run_gus(cfg: DesConfig) -> DesReport {
    let gus = Gus::default();
    Des::new(cfg, &gus).run()
}

/// Cumulative (generated, satisfied, served, cloud, peer) at the last
/// decision boundary at or before `t_ms`.
fn cum_at(r: &DesReport, t_ms: f64) -> (u64, u64, u64, u64, u64) {
    let mut out = (0, 0, 0, 0, 0);
    for f in &r.frames {
        if f.t_ms <= t_ms {
            out = (f.generated, f.satisfied, f.served, f.cloud, f.peer);
        } else {
            break;
        }
    }
    out
}

/// Windowed satisfaction: % of requests generated in `[lo, hi)` that
/// ended satisfied (approximate — completions lag).
fn phase_satisfaction(r: &DesReport, lo_ms: f64, hi_ms: f64) -> f64 {
    let a = cum_at(r, lo_ms);
    let b = cum_at(r, hi_ms);
    if b.0 <= a.0 {
        return 100.0;
    }
    100.0 * (b.1 - a.1) as f64 / (b.0 - a.0) as f64
}

/// Share (%) of requests *served* in `[lo, hi)` that went to the cloud.
fn phase_cloud_share(r: &DesReport, lo_ms: f64, hi_ms: f64) -> f64 {
    let a = cum_at(r, lo_ms);
    let b = cum_at(r, hi_ms);
    let served = b.2.saturating_sub(a.2);
    if served == 0 {
        return 0.0;
    }
    100.0 * (b.3 - a.3) as f64 / served as f64
}

/// Share (%) of requests served in `[lo, hi)` that went to a peer edge.
fn phase_peer_share(r: &DesReport, lo_ms: f64, hi_ms: f64) -> f64 {
    let a = cum_at(r, lo_ms);
    let b = cum_at(r, hi_ms);
    let served = b.2.saturating_sub(a.2);
    if served == 0 {
        return 0.0;
    }
    100.0 * (b.4 - a.4) as f64 / served as f64
}

/// One GUS report per seed in {7, 8, 9}.
fn seed_reports(cfg: &DesConfig) -> Vec<DesReport> {
    [7u64, 8, 9]
        .iter()
        .map(|&seed| {
            let mut c = cfg.clone();
            c.seed = seed;
            run_gus(c)
        })
        .collect()
}

/// Mean of `f` over a set of per-seed reports.
fn mean_over(reports: &[DesReport], f: impl Fn(&DesReport) -> f64) -> f64 {
    reports.iter().map(f).sum::<f64>() / reports.len() as f64
}

// ------------------------------------------------------- built-in scenarios

#[test]
fn every_builtin_conserves_requests_and_records_frames() {
    for name in Script::builtin_names() {
        let mut cfg = base_cfg(3, 1, 4.0);
        cfg.horizon_ms = 60_000.0;
        cfg.script = Some(Script::builtin(name, cfg.horizon_ms, 3).unwrap());
        let r = run_gus(cfg);
        assert!(r.generated > 100, "{name}: expected a real workload");
        assert_eq!(
            r.generated,
            r.served + r.dropped + r.rejected_at_queue,
            "{name}: conservation violated: {r:?}"
        );
        assert_eq!(r.served, r.local + r.cloud + r.peer, "{name}");
        assert!(r.satisfied <= r.served, "{name}");
        assert!(!r.frames.is_empty(), "{name}: frame series missing");
        let applied: u64 = r.frames.iter().map(|f| f.events_applied).sum();
        assert!(applied > 0, "{name}: no scenario event ever applied");
    }
}

#[test]
fn flash_crowd_burst_craters_then_recovers_satisfaction() {
    // No cloud absorber: 3 edges sustain ~7 req/s; the ×8 burst (32/s in
    // [30 s, 66 s)) must overwhelm them, and calm must return after.
    let calm = base_cfg(3, 0, 4.0);
    let mut crowd = calm.clone();
    crowd.script = Some(Script::builtin("flash-crowd", crowd.horizon_ms, 3).unwrap());
    let crowd_runs = seed_reports(&crowd);

    let before = mean_over(&crowd_runs, |r| phase_satisfaction(r, 0.0, 30_000.0));
    let during = mean_over(&crowd_runs, |r| phase_satisfaction(r, 33_000.0, 66_000.0));
    let after = mean_over(&crowd_runs, |r| phase_satisfaction(r, 75_000.0, 120_000.0));
    assert!(
        during < before - 15.0,
        "burst must crater satisfaction: before {before:.1}% vs during {during:.1}%"
    );
    assert!(
        after > during + 15.0,
        "satisfaction must recover after the burst: during {during:.1}% vs after {after:.1}%"
    );

    let with = mean_over(&crowd_runs, |r| r.satisfied_pct());
    let without = mean_over(&seed_reports(&calm), |r| r.satisfied_pct());
    assert!(
        with < without - 2.0,
        "overall: with burst {with:.1}% vs calm {without:.1}%"
    );
}

#[test]
fn edge_failover_satisfaction_dips_then_recovers_after_server_up() {
    // The builtin downs the best-provisioned edge (index 2, EdgeLarge)
    // over [36 s, 78 s). Without a cloud the remaining γ cannot carry
    // 5 req/s, so satisfaction dips, then recovers after ServerUp.
    let steady = base_cfg(3, 0, 5.0);
    let mut failover = steady.clone();
    failover.script = Some(Script::builtin("edge-failover", failover.horizon_ms, 3).unwrap());
    let runs = seed_reports(&failover);

    let before = mean_over(&runs, |r| phase_satisfaction(r, 0.0, 36_000.0));
    let during = mean_over(&runs, |r| phase_satisfaction(r, 45_000.0, 78_000.0));
    let after = mean_over(&runs, |r| phase_satisfaction(r, 87_000.0, 120_000.0));
    assert!(
        during < before - 8.0,
        "outage must hurt: before {before:.1}% vs during {during:.1}%"
    );
    assert!(
        after > during + 8.0,
        "GUS must recover after ServerUp: during {during:.1}% vs after {after:.1}%"
    );

    let with = mean_over(&runs, |r| r.satisfied_pct());
    let without = mean_over(&seed_reports(&steady), |r| r.satisfied_pct());
    assert!(with < without, "outage run cannot beat the steady run");
}

#[test]
fn degraded_backhaul_shifts_gus_away_from_the_cloud() {
    // Backhaul ×30 over [36 s, 84 s): offloading to the (fast) cloud
    // stops meeting deadlines profitably, so GUS re-routes to local/peer
    // serving — and goes back once the drift recovers.
    let healthy = base_cfg(3, 1, 4.0);
    let mut degraded = healthy.clone();
    degraded.script =
        Some(Script::builtin("degraded-backhaul", degraded.horizon_ms, 3).unwrap());
    let degraded_runs = seed_reports(&degraded);
    let healthy_runs = seed_reports(&healthy);

    let window = |r: &DesReport| phase_cloud_share(r, 40_000.0, 84_000.0);
    let with = mean_over(&degraded_runs, window);
    let without = mean_over(&healthy_runs, window);
    assert!(
        with < without - 25.0,
        "cloud share in the degraded window: with {with:.1}% vs without {without:.1}%"
    );
    // After the factor-1.0 recovery event the cloud becomes attractive
    // again.
    let late = mean_over(&degraded_runs, |r| phase_cloud_share(r, 90_000.0, 120_000.0));
    assert!(
        late > with + 20.0,
        "cloud share must rebound after recovery: degraded {with:.1}% vs late {late:.1}%"
    );
    // GUS adapts rather than collapses: satisfaction stays in the same
    // band as the healthy run.
    let sat_with = mean_over(&degraded_runs, |r| r.satisfied_pct());
    let sat_without = mean_over(&healthy_runs, |r| r.satisfied_pct());
    assert!(
        sat_with > sat_without - 15.0,
        "adaptation should bound the damage: {sat_with:.1}% vs {sat_without:.1}%"
    );
}

#[test]
fn commuter_wave_concentration_forces_offloading_then_subsides() {
    // Morning (24 s): 70% of every outer edge's users re-home to edge 0
    // (EdgeSmall) while load doubles; evening (72 s) spreads them back.
    let uniform = base_cfg(4, 0, 5.0);
    let mut wave = uniform.clone();
    wave.script = Some(Script::builtin("commuter-wave", wave.horizon_ms, 4).unwrap());
    let wave_runs = seed_reports(&wave);

    // During the wave the hot edge cannot serve its crowd locally: the
    // peer-offload share of completions must rise sharply vs uniform.
    let window = |r: &DesReport| phase_peer_share(r, 27_000.0, 60_000.0);
    let with = mean_over(&wave_runs, window);
    let without = mean_over(&seed_reports(&uniform), window);
    assert!(
        with > without + 10.0,
        "peer share during the wave: with {with:.1}% vs uniform {without:.1}%"
    );
    // And the system recovers after the evening redistribution.
    let during = mean_over(&wave_runs, |r| phase_satisfaction(r, 27_000.0, 60_000.0));
    let after = mean_over(&wave_runs, |r| phase_satisfaction(r, 81_000.0, 120_000.0));
    assert!(
        after > during + 5.0,
        "evening must relieve the hot edge: during {during:.1}% vs after {after:.1}%"
    );
}

#[test]
fn custom_script_cloud_outage_stops_cloud_offloads_until_server_up() {
    // Scripts are not limited to the built-ins: down the *cloud* (server
    // index 3) over [30 s, 90 s). Cloud completions must stop inside the
    // window (10 s guard for in-flight work) and resume after.
    let mut cfg = base_cfg(3, 1, 3.0);
    cfg.script = Some(Script::new(
        "cloud-outage",
        vec![
            ScriptedEvent { at_ms: 30_000.0, kind: EventKind::ServerDown { server: 3 } },
            ScriptedEvent { at_ms: 90_000.0, kind: EventKind::ServerUp { server: 3 } },
        ],
    ));
    for seed in [7u64, 11] {
        let mut c = cfg.clone();
        c.seed = seed;
        let r = run_gus(c);
        let early = cum_at(&r, 30_000.0);
        let mid_a = cum_at(&r, 40_000.0);
        let mid_b = cum_at(&r, 90_000.0);
        let end = cum_at(&r, 121_000.0);
        assert!(early.3 > 0, "seed {seed}: GUS should use the healthy cloud");
        assert_eq!(
            mid_b.3, mid_a.3,
            "seed {seed}: no cloud completions during the outage window"
        );
        assert!(
            end.3 > mid_b.3,
            "seed {seed}: cloud offloading must resume after ServerUp"
        );
        assert_eq!(r.generated, r.served + r.dropped + r.rejected_at_queue);
    }
}

// --------------------------------------------------- determinism/round-trip

#[test]
fn same_seed_runs_are_byte_identical_with_and_without_script() {
    for script in [
        None,
        Some(Script::builtin("flash-crowd", 60_000.0, 3).unwrap()),
        Some(Script::builtin("edge-failover", 60_000.0, 3).unwrap()),
    ] {
        let mut cfg = base_cfg(3, 1, 4.0);
        cfg.horizon_ms = 60_000.0;
        cfg.script = script;
        let a = run_gus(cfg.clone()).to_json().dump();
        let b = run_gus(cfg.clone()).to_json().dump();
        assert_eq!(a, b, "same seed + same config must be byte-identical");
        assert!(Json::parse(&a).is_ok(), "report dump must stay valid JSON");
    }
}

#[test]
fn script_survives_json_round_trip_and_reruns_identically() {
    let script = Script::builtin("commuter-wave", 60_000.0, 3).unwrap();
    let text = script.to_json().pretty();
    let reloaded = Script::from_json(&Json::parse(&text).unwrap()).unwrap();
    assert_eq!(script, reloaded, "structural round-trip");

    let mut cfg = base_cfg(3, 1, 5.0);
    cfg.horizon_ms = 60_000.0;
    cfg.script = Some(script);
    let a = run_gus(cfg.clone()).to_json().dump();
    cfg.script = Some(reloaded);
    let b = run_gus(cfg).to_json().dump();
    assert_eq!(a, b, "a reloaded script must reproduce the run byte-for-byte");
}

#[test]
fn script_file_round_trip_through_disk() {
    let dir = std::env::temp_dir().join("edgeus_scenario_it");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("failover.json").to_string_lossy().to_string();
    let script = Script::builtin("edge-failover", 90_000.0, 3).unwrap();
    script.save(&path).unwrap();
    let loaded = Script::load(&path).unwrap();
    assert_eq!(script, loaded);
    loaded.validate(4, 3, 10, 4).unwrap();
}

#[test]
fn seeds_differ_under_a_script() {
    let mut cfg = base_cfg(3, 1, 4.0);
    cfg.horizon_ms = 60_000.0;
    cfg.script = Some(Script::builtin("flash-crowd", cfg.horizon_ms, 3).unwrap());
    let a = run_gus(cfg.clone());
    cfg.seed = 99;
    let b = run_gus(cfg);
    assert_ne!(
        (a.generated, a.satisfied),
        (b.generated, b.satisfied),
        "different seeds must explore different arrival processes"
    );
}
