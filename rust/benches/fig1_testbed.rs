//! Bench: regenerate the paper's testbed panels Fig. 1(e)–(h) on the
//! live serving runtime (real PJRT inference per request).
//!
//! Requires `make artifacts`. Scale knobs:
//!   EDGEUS_BENCH_LOADS   comma list of offered loads (default 60,120,240,360)
//!   EDGEUS_BENCH_SCALE   time compression factor (default 50)

use edgeus::serving::TestbedExperiment;

fn main() {
    let loads: Vec<usize> = std::env::var("EDGEUS_BENCH_LOADS")
        .map(|s| s.split(',').filter_map(|p| p.parse().ok()).collect())
        .unwrap_or_else(|_| vec![60, 120, 240, 360]);
    let scale: f64 = std::env::var("EDGEUS_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(50.0);

    let mut exp = TestbedExperiment { loads, ..Default::default() };
    exp.base.time_scale = scale;
    if !std::path::Path::new(&format!("{}/manifest.json", exp.base.artifacts_dir)).exists() {
        eprintln!(
            "SKIP fig1_testbed: no artifacts at {}/ — run `make artifacts`",
            exp.base.artifacts_dir
        );
        return;
    }

    eprintln!(
        "testbed sweep: loads {:?}, policies {:?}, time scale {}x",
        exp.loads, exp.policies, scale
    );
    let t0 = std::time::Instant::now();
    let result = exp.run().expect("testbed experiment failed");
    for (panel, series) in [
        ("fig1e — satisfied users (%)", &result.satisfied),
        ("fig1f — locally processed (%)", &result.local),
        ("fig1g — offloaded to cloud (%)", &result.cloud),
        ("fig1h — offloaded to peer edges (%)", &result.peer),
    ] {
        println!("\n# {panel}\n\n{}", series.to_markdown());
    }
    // Per-run serving performance (latency/throughput of the system).
    println!("\n## per-run serving metrics\n");
    println!("| policy | load | satisfied % | p50 latency (sim ms) | p99 | mean inference (real ms) |");
    println!("|---|---|---|---|---|---|");
    for (policy, load, m) in &result.raw {
        println!(
            "| {} | {} | {:.1} | {:.0} | {:.0} | {:.2} |",
            policy,
            load,
            m.satisfied_pct(),
            m.latency.quantile(0.5),
            m.latency.quantile(0.99),
            m.inference.mean(),
        );
    }
    println!("\ntotal wall time: {:.1}s", t0.elapsed().as_secs_f64());
}
