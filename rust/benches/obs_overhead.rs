//! Bench: observability recorder overhead on the DES hot loop.
//!
//! Three configurations over the same workload and seed:
//!   * `recorder_absent`       — no recorder attached (today's default)
//!   * `recorder_disabled_64k` — a disabled recorder attached (every
//!                                instrumentation site pays its one branch)
//!   * `recorder_enabled_64k`  — full tracing into a 64k-event ring
//!
//! The budget (DESIGN.md §Perf): disabled-vs-absent must stay within 5%.
//! Scale knobs:
//!   EDGEUS_BENCH_HORIZON_S virtual horizon per run (default 120)
//!   EDGEUS_BENCH_RATE      offered load, req/s (default 32)

use edgeus::benchkit::{report, Bencher};
use edgeus::coordinator::scheduler_by_name;
use edgeus::obs::Recorder;
use edgeus::sim::{Des, DesConfig};

fn main() {
    let horizon_s: f64 = std::env::var("EDGEUS_BENCH_HORIZON_S")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(120.0);
    let rate: f64 = std::env::var("EDGEUS_BENCH_RATE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(32.0);

    let cfg = DesConfig {
        horizon_ms: horizon_s * 1e3,
        arrival_rate_per_s: rate,
        ..Default::default()
    };
    let scheduler = scheduler_by_name("gus").expect("gus scheduler");
    let items = horizon_s * rate; // offered requests per iteration

    let bencher = Bencher::new(1, 5).with_items(items);
    let absent = {
        let cfg = cfg.clone();
        bencher.run("recorder_absent", || {
            Des::new(cfg.clone(), scheduler.as_ref()).run().served
        })
    };
    let disabled = {
        let cfg = cfg.clone();
        let rec = Recorder::disabled();
        bencher.run("recorder_disabled_64k", || {
            Des::new(cfg.clone(), scheduler.as_ref())
                .with_recorder(&rec)
                .run()
                .served
        })
    };
    let enabled = {
        let cfg = cfg.clone();
        let rec = Recorder::enabled(1 << 16);
        bencher.run("recorder_enabled_64k", || {
            Des::new(cfg.clone(), scheduler.as_ref())
                .with_recorder(&rec)
                .run()
                .served
        })
    };

    println!("{}", report("DES observability overhead (items = offered requests)", &[
        absent.clone(),
        disabled.clone(),
        enabled.clone(),
    ]));

    let pct = |base: f64, v: f64| if base > 0.0 { 100.0 * (v - base) / base } else { 0.0 };
    let off_overhead = pct(absent.mean_ms, disabled.mean_ms);
    let on_overhead = pct(absent.mean_ms, enabled.mean_ms);
    println!("recorder off  vs absent: {off_overhead:+.2}% mean wall (budget ≤ +5%)");
    println!("recorder on   vs absent: {on_overhead:+.2}% mean wall");
    if off_overhead > 5.0 {
        println!("WARN: disabled-recorder overhead exceeds the 5% budget");
    }
}
