//! Bench: the PJRT inference hot path per (tier, batch) — the L1/L2
//! serving cost that the coordinator's processing-delay profiles wrap.
//!
//! Requires `make artifacts`.

use edgeus::benchkit::{report, Bencher};
use edgeus::runtime::InferenceEngine;

fn main() {
    let dir = std::env::var("EDGEUS_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if !std::path::Path::new(&format!("{dir}/manifest.json")).exists() {
        eprintln!("SKIP runtime_inference: no artifacts at {dir}/ — run `make artifacts`");
        return;
    }
    let engine = InferenceEngine::load(&dir).expect("loading artifacts");
    println!("platform: {}; artifacts: {}", engine.platform(), engine.artifact_names().len());

    let mut results = Vec::new();
    let manifest = engine.manifest.clone();
    for tier in manifest.tiers() {
        for batch in manifest.batches_of(&tier) {
            let info = manifest.find(&tier, batch).unwrap();
            let images = vec![0.5f32; info.input_shape.iter().product()];
            let flops = (info.flops_per_image * batch as u64) as f64;
            let bencher = Bencher::new(3, 15).with_items(batch as f64);
            let name = format!("{}_b{}", tier, batch);
            let r = bencher.run(&name, || engine.infer_tier(&tier, batch, &images).unwrap());
            println!(
                "{name}: {:.3} ms/iter → {:.1} img/s, {:.2} GFLOP/s",
                r.mean_ms,
                r.throughput.unwrap_or(0.0),
                flops / (r.mean_ms / 1e3) / 1e9
            );
            results.push(r);
        }
    }
    println!("{}", report("PJRT inference latency (items = images/iter)", &results));
}
