//! Bench: the paper's in-text optimality claim (T0) — GUS attains ~90%
//! of the exact optimum on small instances — plus B&B solver cost.
//!
//! Scale with EDGEUS_BENCH_INSTANCES (instances per size, default 20).

use edgeus::benchkit::{report, Bencher};
use edgeus::coordinator::gus::Gus;
use edgeus::coordinator::ilp::BranchAndBound;
use edgeus::coordinator::Scheduler;
use edgeus::figures::run_optimal_gap;
use edgeus::model::service::CatalogParams;
use edgeus::model::topology::TopologyParams;
use edgeus::util::rng::Rng;
use edgeus::workload::{build_instance, ScenarioParams, WorkloadParams};

fn main() {
    let instances: usize = std::env::var("EDGEUS_BENCH_INSTANCES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(20);

    // The headline table.
    let sizes = [3, 5, 8, 10, 12];
    let result = run_optimal_gap(&sizes, instances, 7);
    println!("\n# GUS vs exact optimum — {} instances per size\n", instances);
    println!("{}", result.series.to_markdown());
    println!(
        "mean GUS/OPT ratio: {:.3} (paper: ~0.90); proven exact: {:.0}%\n",
        result.mean_ratio,
        100.0 * result.exact_fraction
    );

    // Solver cost scaling.
    let mut results = Vec::new();
    for n in sizes {
        let scenario = ScenarioParams {
            topology: TopologyParams { num_edge: 3, num_cloud: 1, ..Default::default() },
            catalog: CatalogParams { num_services: 4, num_tiers: 3, ..Default::default() },
            workload: WorkloadParams { num_requests: n, ..Default::default() },
        };
        let inst = build_instance(&scenario, &mut Rng::new(99 + n as u64));
        let bencher = Bencher::new(1, 5);
        results.push(bencher.run(&format!("bb_n{n}"), || {
            BranchAndBound::default().solve(&inst)
        }));
        results.push(bencher.run(&format!("gus_n{n}"), || {
            Gus::default().schedule(&inst, &mut Rng::new(0))
        }));
    }
    println!("{}", report("solver cost (B&B vs GUS)", &results));
}
