//! Ablations over the design choices DESIGN.md calls out:
//!
//! 1. **Tier computation-cost model** — the paper's testbed charges one
//!    thread per request regardless of model (`tier_cost_growth = 0`);
//!    what if heavier tiers cost proportionally more γ?
//! 2. **QoS strictness** — hard thresholds (constraints 2b/2c) vs the
//!    paper's "special case" soft mode where thresholds are suggestions.
//! 3. **Satisfaction weights** — w_a vs w_c trade-off (the paper fixes
//!    both at 1; its future work calls out differing priorities).
//! 4. **Cloud sizing** — the paper's "resource-constrained cloud" claim:
//!    how satisfaction moves as the cloud grows from edge-class to
//!    effectively unconstrained.
//! 5. **Bandwidth-estimator** — the paper's two-sample average vs a
//!    static estimate, on a drifting channel.

use edgeus::coordinator::gus::Gus;
use edgeus::coordinator::us::ConstraintMode;
use edgeus::coordinator::Scheduler;
use edgeus::model::service::CatalogParams;
use edgeus::net::{BandwidthEstimator, Link};
use edgeus::sim::MonteCarlo;
use edgeus::util::rng::Rng;
use edgeus::util::stats::Accumulator;
use edgeus::workload::{build_instance, ScenarioParams, WorkloadParams};

fn runs() -> usize {
    std::env::var("EDGEUS_BENCH_RUNS").ok().and_then(|s| s.parse().ok()).unwrap_or(150)
}

fn mc(scenario: ScenarioParams) -> MonteCarlo {
    MonteCarlo { scenario, runs: runs(), base_seed: 7, ..Default::default() }
}

fn main() {
    ablation_tier_cost();
    ablation_soft_qos();
    ablation_weights();
    ablation_cloud_sizing();
    ablation_bandwidth_estimator();
}

fn ablation_tier_cost() {
    println!("\n## ablation 1 — tier computation-cost model (GUS satisfied %)\n");
    println!("| tier_cost_growth | gus | happy-computation | local-all |");
    println!("|---|---|---|---|");
    for growth in [0.0, 0.5, 1.0, 2.0] {
        let scenario = ScenarioParams {
            catalog: CatalogParams { tier_cost_growth: growth, ..Default::default() },
            ..Default::default()
        };
        let stats = mc(scenario).run();
        let by = |n: &str| stats.iter().find(|s| s.name == n).unwrap().satisfied_pct.mean();
        println!(
            "| {growth} | {:.2} | {:.2} | {:.2} |",
            by("gus"),
            by("happy-computation"),
            by("local-all")
        );
    }
    println!(
        "\n(costlier high-accuracy tiers shrink the effective capacity the greedy\n\
         consumes — the flat model matches the paper's one-thread-per-request testbed)"
    );
}

fn ablation_soft_qos() {
    println!("\n## ablation 2 — strict vs soft QoS (the paper's special case)\n");
    println!("| mode | served % | satisfied % | objective |");
    println!("|---|---|---|---|");
    for (name, mode) in [
        ("strict (2b)/(2c)", ConstraintMode::STRICT),
        ("soft (suggestions)", ConstraintMode::SOFT_QOS),
    ] {
        let mut served = Accumulator::new();
        let mut satisfied = Accumulator::new();
        let mut objective = Accumulator::new();
        for run in 0..runs() {
            let mut rng = Rng::new(7 ^ (run as u64).wrapping_mul(0x9E37));
            let inst = build_instance(&ScenarioParams::default(), &mut rng);
            let s = Gus::with_mode(mode).schedule(&inst, &mut rng);
            served.push(100.0 * s.served() as f64 / inst.num_requests() as f64);
            satisfied.push(s.satisfied_pct(&inst));
            objective.push(s.objective());
        }
        println!(
            "| {name} | {:.2} | {:.2} | {:.4} |",
            served.mean(),
            satisfied.mean(),
            objective.mean()
        );
    }
    println!("\n(soft mode serves more users but satisfies the same or fewer — extra\n\
         assignments violate a threshold by construction)");
}

fn ablation_weights() {
    println!("\n## ablation 3 — satisfaction weights w_a vs w_c (GUS)\n");
    println!("| (w_a, w_c) | satisfied % | mean accuracy slack | mean time slack |");
    println!("|---|---|---|---|");
    for (wa, wc) in [(1.0, 1.0), (1.0, 0.25), (0.25, 1.0), (0.0, 1.0), (1.0, 0.0)] {
        let mut satisfied = Accumulator::new();
        let mut acc_slack = Accumulator::new();
        let mut time_slack = Accumulator::new();
        for run in 0..runs() {
            let mut rng = Rng::new(11 ^ (run as u64).wrapping_mul(0x9E37));
            let scenario = ScenarioParams {
                workload: WorkloadParams { w_accuracy: wa, w_completion: wc, ..Default::default() },
                ..Default::default()
            };
            let inst = build_instance(&scenario, &mut rng);
            let s = Gus::default().schedule(&inst, &mut rng);
            satisfied.push(s.satisfied_pct(&inst));
            for a in s.slots.iter().flatten() {
                let req = &inst.requests[a.request.0];
                acc_slack.push(a.candidate.accuracy_pct - req.min_accuracy_pct);
                time_slack.push(req.max_completion_ms - a.candidate.completion_ms);
            }
        }
        println!(
            "| ({wa}, {wc}) | {:.2} | {:.1} pp | {:.0} ms |",
            satisfied.mean(),
            acc_slack.mean(),
            time_slack.mean()
        );
    }
    println!("\n(accuracy-weighted users get higher-tier models; delay-weighted users\n\
         get faster placements — the knob works end to end)");
}

fn ablation_cloud_sizing() {
    println!("\n## ablation 4 — how constrained must the cloud be to matter?\n");
    println!("| cloud γ scale | gus satisfied % | cloud share of decisions % |");
    println!("|---|---|---|");
    for scale in [0.25, 1.0, 4.0, 16.0] {
        let mut satisfied = Accumulator::new();
        let mut cloud_share = Accumulator::new();
        for run in 0..runs() {
            let mut rng = Rng::new(13 ^ (run as u64).wrapping_mul(0x9E37));
            let mut inst = build_instance(&ScenarioParams::default(), &mut rng);
            for s in &mut inst.topology.to_mut().servers {
                if s.is_cloud() {
                    s.gamma *= scale;
                    s.eta *= scale;
                }
            }
            let s = Gus::default().schedule(&inst, &mut rng);
            satisfied.push(s.satisfied_pct(&inst));
            let mix = s.decision_mix_pct(&inst);
            cloud_share.push(mix[1]);
        }
        println!("| {scale} | {:.2} | {:.2} |", satisfied.mean(), cloud_share.mean());
    }
    println!("\n(the paper's resource-constrained-cloud assumption is the regime where\n\
         scheduling matters; with a huge cloud, offload-all becomes near-optimal)");
}

fn ablation_bandwidth_estimator() {
    println!("\n## ablation 5 — bandwidth estimator on a drifting channel\n");
    // Channel drifts 600 -> 200 bytes/ms; compare expected-delay error.
    let mut rng = Rng::new(17);
    let mut est = BandwidthEstimator::new(600.0);
    let mut est_err = Accumulator::new();
    let mut static_err = Accumulator::new();
    for step in 0..200 {
        let true_bw = 600.0 - 400.0 * (step as f64 / 200.0);
        let link = Link::new(true_bw, 0.2, 0.0);
        let (true_delay, realized) = link.transfer(14_000, &mut rng);
        est_err.push((est.expected_delay_ms(14_000) - true_delay).abs());
        static_err.push((14_000.0 / 600.0 - true_delay).abs());
        est.observe(realized);
    }
    println!("| estimator | mean |delay error| (ms) |");
    println!("|---|---|");
    println!("| paper E[B]=(B_t+B_t-1)/2 | {:.2} |", est_err.mean());
    println!("| static 600 bytes/ms | {:.2} |", static_err.mean());
    println!("\n(the paper's adaptive rule tracks the drift; a static estimate\n\
         accumulates error as the channel degrades)");
}
