//! Bench + CI gate for the allocation-free DES hot path.
//!
//! For each offered-load point (low / mid / high), runs the same seeded
//! simulation three ways — `Des::run` with the GUS rank cache (`gus`),
//! `Des::run` with the cache disabled (`gus-nocache`, the legacy
//! enumerate+sort path), and `Des::run_reference` (the pre-pooling
//! clone-the-world oracle) — and reports simulated request throughput,
//! wall-time per decision frame, the pooled-vs-reference speedup, the
//! cache-on-vs-cache-off speedup, and the steady-state cache hit rate.
//! Results are written to `BENCH_des.json` (CI uploads it as an
//! artifact; committing that artifact refreshes the regression baseline).
//!
//! Gates (exit code 1 on failure):
//!   * regression — if a measured baseline exists at
//!     `EDGEUS_BENCH_BASELINE` (default `BENCH_des.json`), pooled
//!     wall-time per decision frame must not regress more than 25%
//!     at any rate;
//!   * speedup — with `EDGEUS_BENCH_GATE_SPEEDUP=1`, the pooled path
//!     must be ≥3× the reference throughput at the highest rate;
//!   * cache — with `EDGEUS_BENCH_GATE_CACHE=1`, the plain-world
//!     steady-state cache hit rate must be ≥90% at every rate, and the
//!     cached path must be ≥2× the uncached path at the highest rate.
//!
//! Scale knobs:
//!   EDGEUS_BENCH_RATES     comma list of offered loads (default
//!                          1000,10000,100000 req/s)
//!   EDGEUS_BENCH_HORIZON_S virtual horizon per run (default 10)
//!   EDGEUS_BENCH_ITERS     timed iterations per case (default 5)
//!   EDGEUS_BENCH_SMOKE     =1 shrinks horizon/iters for PR CI
//!   EDGEUS_BENCH_OUT       output path (default BENCH_des.json)

use edgeus::benchkit::{report, Bencher};
use edgeus::coordinator::scheduler_by_name;
use edgeus::sim::{Des, DesConfig};
use edgeus::util::json::Json;

struct RatePoint {
    rate: f64,
    generated: u64,
    decisions: u64,
    pooled_ms: f64,
    nocache_ms: f64,
    reference_ms: f64,
    sim_req_per_s: f64,
    wall_us_per_frame: f64,
    wall_us_per_frame_nocache: f64,
    speedup: f64,
    cache_speedup: f64,
    cache_hit_rate: f64,
    cache_rebuilds: u64,
}

fn env_f64(key: &str, default: f64) -> f64 {
    std::env::var(key).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn main() {
    let smoke = std::env::var("EDGEUS_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    let horizon_s = env_f64("EDGEUS_BENCH_HORIZON_S", if smoke { 3.0 } else { 10.0 });
    let iters = env_f64("EDGEUS_BENCH_ITERS", if smoke { 3.0 } else { 5.0 }) as usize;
    let rates: Vec<f64> = std::env::var("EDGEUS_BENCH_RATES")
        .ok()
        .map(|s| s.split(',').filter_map(|x| x.trim().parse().ok()).collect())
        .unwrap_or_else(|| vec![1_000.0, 10_000.0, 100_000.0]);

    let scheduler = scheduler_by_name("gus").expect("gus scheduler");
    let nocache = scheduler_by_name("gus-nocache").expect("gus-nocache scheduler");
    let mut points = Vec::with_capacity(rates.len());
    let mut tables = Vec::new();

    for &rate in &rates {
        let cfg = DesConfig {
            horizon_ms: horizon_s * 1e3,
            arrival_rate_per_s: rate,
            ..Default::default()
        };
        let probe = Des::new(cfg.clone(), scheduler.as_ref()).run();
        let bencher = Bencher::new(1, iters).with_items(probe.generated as f64);
        let pooled = {
            let cfg = cfg.clone();
            bencher.run(&format!("pooled_{rate}rps"), || {
                Des::new(cfg.clone(), scheduler.as_ref()).run().served
            })
        };
        let pooled_nocache = {
            let cfg = cfg.clone();
            bencher.run(&format!("nocache_{rate}rps"), || {
                Des::new(cfg.clone(), nocache.as_ref()).run().served
            })
        };
        let reference = {
            let cfg = cfg.clone();
            bencher.run(&format!("reference_{rate}rps"), || {
                Des::new(cfg.clone(), scheduler.as_ref()).run_reference().served
            })
        };
        let point = RatePoint {
            rate,
            generated: probe.generated,
            decisions: probe.decisions,
            pooled_ms: pooled.mean_ms,
            nocache_ms: pooled_nocache.mean_ms,
            reference_ms: reference.mean_ms,
            sim_req_per_s: probe.generated as f64 / (pooled.mean_ms / 1e3).max(1e-12),
            wall_us_per_frame: pooled.mean_ms * 1e3 / probe.decisions.max(1) as f64,
            wall_us_per_frame_nocache: pooled_nocache.mean_ms * 1e3
                / probe.decisions.max(1) as f64,
            speedup: reference.mean_ms / pooled.mean_ms.max(1e-12),
            cache_speedup: pooled_nocache.mean_ms / pooled.mean_ms.max(1e-12),
            cache_hit_rate: probe.cache_hit_rate(),
            cache_rebuilds: probe.cache_rebuilds,
        };
        tables.push(report(
            &format!("des_hot_path @ {rate} req/s offered (items = generated requests)"),
            &[pooled, pooled_nocache, reference],
        ));
        points.push(point);
    }

    for t in &tables {
        println!("{t}");
    }
    println!(
        "| rate (req/s) | generated | decisions | sim req/s | wall µs/frame \
         | µs/frame nocache | vs reference | vs nocache | cache hit rate |"
    );
    println!("|---|---|---|---|---|---|---|---|---|");
    for p in &points {
        println!(
            "| {} | {} | {} | {:.0} | {:.1} | {:.1} | {:.2}x | {:.2}x | {:.1}% |",
            p.rate,
            p.generated,
            p.decisions,
            p.sim_req_per_s,
            p.wall_us_per_frame,
            p.wall_us_per_frame_nocache,
            p.speedup,
            p.cache_speedup,
            100.0 * p.cache_hit_rate
        );
    }

    // Emit BENCH_des.json.
    let out_path =
        std::env::var("EDGEUS_BENCH_OUT").unwrap_or_else(|_| "BENCH_des.json".to_string());
    let baseline_path =
        std::env::var("EDGEUS_BENCH_BASELINE").unwrap_or_else(|_| "BENCH_des.json".to_string());
    // Read the committed baseline BEFORE overwriting the output file
    // (default config points both at the same path).
    let baseline = std::fs::read_to_string(&baseline_path)
        .ok()
        .and_then(|text| Json::parse(&text).ok());

    let json = Json::obj(vec![
        ("bench", Json::str("des_hot_path")),
        ("status", Json::str("measured")),
        ("policy", Json::str("gus")),
        ("horizon_s", Json::num(horizon_s)),
        ("iters", Json::num(iters as f64)),
        ("smoke", Json::num(if smoke { 1.0 } else { 0.0 })),
        (
            "rates",
            Json::arr(points.iter().map(|p| {
                Json::obj(vec![
                    ("rate_per_s", Json::num(p.rate)),
                    ("generated", Json::num(p.generated as f64)),
                    ("decisions", Json::num(p.decisions as f64)),
                    ("pooled_wall_ms", Json::num(p.pooled_ms)),
                    ("nocache_wall_ms", Json::num(p.nocache_ms)),
                    ("reference_wall_ms", Json::num(p.reference_ms)),
                    ("sim_req_per_s", Json::num(p.sim_req_per_s)),
                    ("wall_us_per_frame", Json::num(p.wall_us_per_frame)),
                    ("wall_us_per_frame_nocache", Json::num(p.wall_us_per_frame_nocache)),
                    ("speedup_vs_reference", Json::num(p.speedup)),
                    ("speedup_vs_nocache", Json::num(p.cache_speedup)),
                    ("cache_hit_rate", Json::num(p.cache_hit_rate)),
                    ("cache_rebuilds", Json::num(p.cache_rebuilds as f64)),
                ])
            })),
        ),
    ]);
    std::fs::write(&out_path, json.dump()).expect("write BENCH_des.json");
    println!("\nwrote {out_path}");

    let mut failed = false;

    // Gate 1: wall-time per decision frame vs the committed baseline.
    match baseline {
        Some(b) if b.get("status").as_str() == Some("measured") => {
            for p in &points {
                let base = b
                    .get("rates")
                    .as_arr()
                    .into_iter()
                    .flatten()
                    .find(|r| r.get("rate_per_s").as_f64() == Some(p.rate))
                    .and_then(|r| r.get("wall_us_per_frame").as_f64());
                match base {
                    Some(base_us) if base_us > 0.0 => {
                        let delta = 100.0 * (p.wall_us_per_frame - base_us) / base_us;
                        println!(
                            "gate: {} req/s wall/frame {:.1}µs vs baseline {:.1}µs ({delta:+.1}%)",
                            p.rate, p.wall_us_per_frame, base_us
                        );
                        if delta > 25.0 {
                            eprintln!("FAIL: >25% frame wall-time regression at {} req/s", p.rate);
                            failed = true;
                        }
                    }
                    _ => println!("gate: no baseline entry for {} req/s, skipping", p.rate),
                }
            }
        }
        _ => println!("gate: no measured baseline at {baseline_path}, regression gate skipped"),
    }

    // Gate 2: the tentpole's throughput claim, at the highest rate.
    let gate_speedup =
        std::env::var("EDGEUS_BENCH_GATE_SPEEDUP").map(|v| v == "1").unwrap_or(false);
    if let Some(top) = points.last() {
        println!(
            "speedup at {} req/s: {:.2}x (target ≥3x{})",
            top.rate,
            top.speedup,
            if gate_speedup { ", enforced" } else { "" }
        );
        if gate_speedup && top.speedup < 3.0 {
            eprintln!("FAIL: pooled hot path is <3x the reference at the highest load");
            failed = true;
        }
    }

    // Gate 3: the rank cache's claims. On a plain world (no scenario
    // events) classes only miss on first touch, so steady state must be
    // ≥90% warm; and serving from the cache must beat the legacy
    // enumerate+sort path ≥2× at the highest load.
    let gate_cache =
        std::env::var("EDGEUS_BENCH_GATE_CACHE").map(|v| v == "1").unwrap_or(false);
    for p in &points {
        println!(
            "cache: {} req/s hit rate {:.1}% ({} rebuilds), cached vs nocache {:.2}x{}",
            p.rate,
            100.0 * p.cache_hit_rate,
            p.cache_rebuilds,
            p.cache_speedup,
            if gate_cache { " (enforced: ≥90%, top rate ≥2x)" } else { "" }
        );
        if gate_cache && p.cache_hit_rate < 0.9 {
            eprintln!(
                "FAIL: plain-world steady-state cache hit rate {:.1}% < 90% at {} req/s",
                100.0 * p.cache_hit_rate,
                p.rate
            );
            failed = true;
        }
    }
    if let Some(top) = points.last() {
        if gate_cache && top.cache_speedup < 2.0 {
            eprintln!(
                "FAIL: rank cache is {:.2}x (<2x) the uncached path at {} req/s",
                top.cache_speedup, top.rate
            );
            failed = true;
        }
    }

    if failed {
        std::process::exit(1);
    }
}
