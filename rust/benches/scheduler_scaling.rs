//! Bench: L3 scheduler hot path. The paper assumes decision time is
//! negligible relative to the 3000 ms decision frame; this bench verifies
//! that and tracks the GUS inner loop's scaling (O(|N| (|L||M|)²) worst
//! case from the per-request candidate sort).

use edgeus::benchkit::{report, Bencher};
use edgeus::coordinator::{all_schedulers, Scheduler};
use edgeus::model::service::CatalogParams;
use edgeus::model::topology::TopologyParams;
use edgeus::util::rng::Rng;
use edgeus::workload::{build_instance, ScenarioParams, WorkloadParams};

fn main() {
    // Paper-default shape, sweeping N.
    let mut results = Vec::new();
    for n in [100usize, 500, 1000, 5000] {
        let scenario = ScenarioParams {
            workload: WorkloadParams { num_requests: n, ..Default::default() },
            ..Default::default()
        };
        let inst = build_instance(&scenario, &mut Rng::new(3));
        let bencher = Bencher::new(1, 8).with_items(n as f64);
        for sched in all_schedulers() {
            if n > 1000 && sched.name() != "gus" {
                continue; // deep sweep only for the paper's algorithm
            }
            let name = format!("{}_n{}", sched.name(), n);
            results.push(bencher.run(&name, || {
                sched.schedule(&inst, &mut Rng::new(0))
            }));
        }
    }
    println!("{}", report("scheduler latency (items = requests/decision)", &results));

    // Candidate-set scaling: |M| and |L| sweeps at N=100.
    let mut shape_results = Vec::new();
    for (m, l) in [(10usize, 10usize), (20, 10), (10, 20), (30, 30)] {
        let scenario = ScenarioParams {
            topology: TopologyParams { num_edge: m - 1, num_cloud: 1, ..Default::default() },
            catalog: CatalogParams { num_tiers: l, ..Default::default() },
            workload: WorkloadParams { num_requests: 100, ..Default::default() },
        };
        let inst = build_instance(&scenario, &mut Rng::new(5));
        let bencher = Bencher::new(1, 5).with_items(100.0);
        let gus = edgeus::coordinator::gus::Gus::default();
        shape_results.push(bencher.run(&format!("gus_M{m}_L{l}"), || {
            gus.schedule(&inst, &mut Rng::new(0))
        }));
    }
    println!("{}", report("GUS vs candidate-set size (M servers x L tiers)", &shape_results));

    // The paper's feasibility condition: a decision for the testbed frame
    // (N ≤ ~20 queued) must be far below the 3000 ms frame.
    let scenario = ScenarioParams {
        workload: WorkloadParams { num_requests: 20, ..Default::default() },
        ..Default::default()
    };
    let inst = build_instance(&scenario, &mut Rng::new(9));
    let gus = edgeus::coordinator::gus::Gus::default();
    let r = Bencher::new(2, 20).run("gus_frame_n20", || gus.schedule(&inst, &mut Rng::new(0)));
    println!(
        "\nframe feasibility: GUS decision for 20 queued requests = {:.3} ms \
         ({}x under the 3000 ms frame)\n",
        r.mean_ms,
        (3000.0 / r.mean_ms) as u64
    );
}
