//! Bench: regenerate the paper's numerical panels Fig. 1(a)–(d).
//!
//! Prints the full satisfied-% series per policy (the paper's plotted
//! data) plus harness timings for the Monte-Carlo sweeps. Scale with
//! `EDGEUS_BENCH_RUNS` (Monte-Carlo runs per sweep point; default 200 —
//! the paper used 20 000, which the same command reproduces given time).

use edgeus::benchkit::{report, Bencher};
use edgeus::figures::{run_numerical_sweep, NumericalConfig, NumericalFigure};

fn main() {
    let runs: usize = std::env::var("EDGEUS_BENCH_RUNS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(200);
    let cfg = NumericalConfig { runs, ..Default::default() };

    let mut results = Vec::new();
    for figure in [
        NumericalFigure::Fig1a,
        NumericalFigure::Fig1b,
        NumericalFigure::Fig1c,
        NumericalFigure::Fig1d,
    ] {
        let sweep = figure.default_sweep();
        let bencher = Bencher::new(0, 1).with_items((runs * sweep.len()) as f64);
        let mut series = None;
        let r = bencher.run(figure.id(), || {
            series = Some(run_numerical_sweep(figure, &cfg, &sweep));
        });
        let series = series.unwrap();
        println!(
            "\n# {} — satisfied users (%) vs {} [{} MC runs/point]\n",
            figure.id(),
            series.x_label,
            runs
        );
        println!("{}", series.to_markdown());
        results.push(r);
    }
    println!("{}", report("fig1 numerical sweeps (items = MC instances)", &results));
}
