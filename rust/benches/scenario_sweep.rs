//! Bench: the scenario sweep runner — seeds × policies DES fan-out on
//! `std::thread` workers. Tracks wall-clock scaling vs worker count for
//! each built-in scenario (the sweep should scale near-linearly until
//! the per-run allocation traffic binds).
//!
//! Scale knobs:
//!   EDGEUS_BENCH_SEEDS     seeds per policy (default 8)
//!   EDGEUS_BENCH_HORIZON_S virtual horizon per run (default 60)

use edgeus::benchkit::{report, Bencher};
use edgeus::scenario::{run_sweep, Script, SweepConfig};
use edgeus::sim::DesConfig;

fn main() {
    let seeds: usize = std::env::var("EDGEUS_BENCH_SEEDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(8);
    let horizon_s: f64 = std::env::var("EDGEUS_BENCH_HORIZON_S")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(60.0);

    let mut base = DesConfig {
        horizon_ms: horizon_s * 1e3,
        arrival_rate_per_s: 8.0,
        ..Default::default()
    };
    let num_edges = base.scenario.topology.num_edge;
    let policies = vec!["gus".to_string(), "local-all".to_string()];

    let mut results = Vec::new();
    for name in Script::builtin_names() {
        base.script = Script::builtin(name, base.horizon_ms, num_edges);
        for threads in [1usize, 4] {
            let cfg = SweepConfig {
                base: base.clone(),
                policies: policies.clone(),
                num_seeds: seeds,
                threads,
            };
            let bencher = Bencher::new(1, 3).with_items((seeds * policies.len()) as f64);
            results.push(bencher.run(&format!("{name}_t{threads}"), || run_sweep(&cfg)));
        }
    }
    println!(
        "{}",
        report("scenario sweep (items = DES runs per iteration)", &results)
    );

    // Summary sanity line: one full sweep's aggregate per policy.
    base.script = Script::builtin("flash-crowd", base.horizon_ms, num_edges);
    let cfg = SweepConfig { base, policies, num_seeds: seeds, threads: 4 };
    for sw in run_sweep(&cfg) {
        println!(
            "flash-crowd {}: satisfied {:.1}% ±{:.1}, dropped {:.1}%",
            sw.policy,
            sw.satisfied_pct.mean(),
            sw.satisfied_pct.ci95(),
            sw.drop_pct.mean(),
        );
    }
}
