//! edgeus-lint — repo-local invariant linter, run blocking in CI
//! (`lint-invariants` job). Four checks, documented in DESIGN.md
//! §Static-Analysis:
//!
//! * **no-alloc** — inside `// lint:no-alloc:begin` / `:end` fenced
//!   regions, allocation-shaped tokens are forbidden unless the line
//!   carries `// lint:allow(alloc)`. The DES event loop, GUS fill, and
//!   candidate enumeration must each carry at least one fence.
//! * **no-unwrap** — `.unwrap()` / `.expect("` are forbidden in library
//!   code outside `#[cfg(test)]` modules. The mutex-poisoning idioms
//!   `.lock().unwrap()` and `.into_inner().unwrap()` are exempt (a
//!   poisoned lock means a worker already panicked); anything else
//!   needs a `// lint:allow(unwrap)` marker stating why.
//! * **usage-sync** — every `Some("name") => cmd_*` dispatch arm in
//!   `main.rs` must be mentioned in `print_usage`.
//! * **drop-taxonomy** — every `DropReason` variant must appear in
//!   `ALL`, in `as_str`, and at a recording site outside `obs/mod.rs`;
//!   at least one site must pre-declare the full taxonomy via
//!   `for reason in DropReason::ALL` so exporters emit every label.

use std::fmt;
use std::path::Path;

/// One rule breach at a file:line.
#[derive(Debug)]
struct Violation {
    file: String,
    line: usize,
    rule: &'static str,
    message: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

/// A source tree as (relative path, content) pairs — checks are pure so
/// the unit tests can feed synthetic trees.
type Files = Vec<(String, String)>;

const ALLOC_TOKENS: [&str; 10] = [
    "Vec::new",
    "vec![",
    "to_vec(",
    ".clone()",
    "Box::new",
    "String::new",
    "to_string(",
    "format!(",
    ".collect(",
    "with_capacity(",
];

/// Files that must contain at least one no-alloc fence (the hot paths
/// the throughput gate depends on).
const FENCED_FILES: [&str; 5] = [
    "sim/des.rs",
    "coordinator/gus.rs",
    "coordinator/rank_cache.rs",
    "model/instance.rs",
    "serving/mod.rs",
];

fn is_comment_line(line: &str) -> bool {
    line.trim_start().starts_with("//")
}

/// Check A: allocation tokens inside `lint:no-alloc` fences.
fn check_fences(files: &Files) -> Vec<Violation> {
    let mut out = Vec::new();
    for (path, text) in files {
        let mut open_at: Option<usize> = None;
        let mut fences = 0usize;
        for (n, line) in text.lines().enumerate() {
            let ln = n + 1;
            if line.contains("lint:no-alloc:begin") {
                if open_at.is_some() {
                    out.push(Violation {
                        file: path.clone(),
                        line: ln,
                        rule: "no-alloc",
                        message: "nested lint:no-alloc:begin".into(),
                    });
                }
                open_at = Some(ln);
                fences += 1;
                continue;
            }
            if line.contains("lint:no-alloc:end") {
                if open_at.is_none() {
                    out.push(Violation {
                        file: path.clone(),
                        line: ln,
                        rule: "no-alloc",
                        message: "lint:no-alloc:end without begin".into(),
                    });
                }
                open_at = None;
                continue;
            }
            if open_at.is_none()
                || is_comment_line(line)
                || line.contains("lint:allow(alloc)")
            {
                continue;
            }
            for tok in ALLOC_TOKENS {
                if line.contains(tok) {
                    out.push(Violation {
                        file: path.clone(),
                        line: ln,
                        rule: "no-alloc",
                        message: format!("allocation token `{tok}` inside no-alloc fence"),
                    });
                }
            }
        }
        if let Some(begin) = open_at {
            out.push(Violation {
                file: path.clone(),
                line: begin,
                rule: "no-alloc",
                message: "unclosed lint:no-alloc:begin".into(),
            });
        }
    }
    for want in FENCED_FILES {
        match files.iter().find(|(p, _)| p.ends_with(want)) {
            Some((p, text)) if !text.contains("lint:no-alloc:begin") => {
                out.push(Violation {
                    file: p.clone(),
                    line: 1,
                    rule: "no-alloc",
                    message: "hot-path file must carry at least one no-alloc fence".into(),
                });
            }
            _ => {}
        }
    }
    out
}

/// Count non-overlapping occurrences of `needle` in `hay`.
fn occurrences(hay: &str, needle: &str) -> usize {
    hay.matches(needle).count()
}

/// Check B: `.unwrap()` / `.expect("` in library code outside tests.
fn check_unwraps(files: &Files) -> Vec<Violation> {
    let mut out = Vec::new();
    for (path, text) in files {
        if path.ends_with("main.rs") {
            continue; // the CLI binary may exit loudly
        }
        // Skip-state for `#[cfg(test)] mod ...` blocks: once the mod's
        // opening brace is seen, swallow lines until its depth closes.
        let mut pending_test_mod = false;
        let mut skip_depth: i64 = 0;
        for (n, line) in text.lines().enumerate() {
            let ln = n + 1;
            if skip_depth > 0 {
                skip_depth += line.matches('{').count() as i64;
                skip_depth -= line.matches('}').count() as i64;
                continue;
            }
            if line.contains("#[cfg(test)]") {
                pending_test_mod = true;
                continue;
            }
            if pending_test_mod {
                if line.trim_start().starts_with("mod ") || line.contains(" mod ") {
                    skip_depth = line.matches('{').count() as i64
                        - line.matches('}').count() as i64;
                    if skip_depth <= 0 {
                        skip_depth = 0; // `mod x;` — nothing inline to skip
                    }
                    pending_test_mod = false;
                    continue;
                }
                // Other cfg(test) items (fns, consts) are still test-only:
                // skip just this item header line and keep scanning.
                pending_test_mod = false;
            }
            if is_comment_line(line) || line.contains("lint:allow(unwrap)") {
                continue;
            }
            let raw = occurrences(line, ".unwrap()");
            let exempt = occurrences(line, ".lock().unwrap()")
                + occurrences(line, ".into_inner().unwrap()");
            if raw > exempt {
                out.push(Violation {
                    file: path.clone(),
                    line: ln,
                    rule: "no-unwrap",
                    message: "`.unwrap()` in library code (mark lint:allow(unwrap) with a reason, or handle the error)".into(),
                });
            }
            if line.contains(".expect(\"") {
                out.push(Violation {
                    file: path.clone(),
                    line: ln,
                    rule: "no-unwrap",
                    message: "`.expect(..)` in library code (mark lint:allow(unwrap) with a reason, or handle the error)".into(),
                });
            }
        }
    }
    out
}

/// Check C: every dispatch arm in main.rs is documented in print_usage.
fn check_usage_sync(files: &Files) -> Vec<Violation> {
    let mut out = Vec::new();
    let Some((path, text)) = files.iter().find(|(p, _)| p.ends_with("main.rs")) else {
        return out;
    };
    let usage = match text.find("fn print_usage") {
        Some(start) => match text[start..].find("\n}") {
            Some(end) => &text[start..start + end],
            None => "",
        },
        None => "",
    };
    for (n, line) in text.lines().enumerate() {
        if !(line.contains("Some(\"") && line.contains("=> cmd_")) {
            continue;
        }
        let Some(rest) = line.split("Some(\"").nth(1) else { continue };
        let Some(name) = rest.split('"').next() else { continue };
        if !usage.contains(name) {
            out.push(Violation {
                file: path.clone(),
                line: n + 1,
                rule: "usage-sync",
                message: format!("subcommand `{name}` missing from print_usage"),
            });
        }
    }
    if usage.is_empty() {
        out.push(Violation {
            file: path.clone(),
            line: 1,
            rule: "usage-sync",
            message: "print_usage not found in main.rs".into(),
        });
    }
    out
}

/// Check D: the DropReason taxonomy is closed end-to-end.
fn check_drop_taxonomy(files: &Files) -> Vec<Violation> {
    let mut out = Vec::new();
    let Some((obs_path, obs)) = files.iter().find(|(p, _)| p.ends_with("obs/mod.rs"))
    else {
        return out;
    };
    // Variant names: identifier-comma lines inside `pub enum DropReason`.
    let mut variants: Vec<&str> = Vec::new();
    if let Some(start) = obs.find("pub enum DropReason") {
        for line in obs[start..].lines().skip(1) {
            let t = line.trim();
            if t.starts_with('}') {
                break;
            }
            if t.starts_with("//") || t.is_empty() {
                continue;
            }
            let name = t.trim_end_matches(',');
            if !name.is_empty() && name.chars().all(|c| c.is_ascii_alphanumeric()) {
                variants.push(name);
            }
        }
    }
    if variants.is_empty() {
        out.push(Violation {
            file: obs_path.clone(),
            line: 1,
            rule: "drop-taxonomy",
            message: "could not parse DropReason variants".into(),
        });
        return out;
    }
    let section = |anchor: &str| -> &str {
        match obs.find(anchor) {
            Some(s) => match obs[s..].find("\n    }") {
                Some(e) => &obs[s..s + e],
                None => "",
            },
            None => "",
        }
    };
    let all_block = match obs.find("pub const ALL") {
        Some(s) => match obs[s..].find("];") {
            Some(e) => &obs[s..s + e],
            None => "",
        },
        None => "",
    };
    let as_str_block = section("fn as_str");
    for v in &variants {
        let qualified = format!("DropReason::{v}");
        if !all_block.contains(qualified.as_str()) {
            out.push(Violation {
                file: obs_path.clone(),
                line: 1,
                rule: "drop-taxonomy",
                message: format!("variant {v} missing from DropReason::ALL"),
            });
        }
        if !as_str_block.contains(qualified.as_str()) {
            out.push(Violation {
                file: obs_path.clone(),
                line: 1,
                rule: "drop-taxonomy",
                message: format!("variant {v} missing from DropReason::as_str"),
            });
        }
        let used_elsewhere = files.iter().any(|(p, t)| {
            !p.ends_with("obs/mod.rs") && t.contains(qualified.as_str())
        });
        if !used_elsewhere {
            out.push(Violation {
                file: obs_path.clone(),
                line: 1,
                rule: "drop-taxonomy",
                message: format!("variant {v} is never recorded outside obs/mod.rs"),
            });
        }
    }
    let declared = files
        .iter()
        .any(|(_, t)| t.contains("for reason in DropReason::ALL"));
    if !declared {
        out.push(Violation {
            file: obs_path.clone(),
            line: 1,
            rule: "drop-taxonomy",
            message: "no site pre-declares the full taxonomy (for reason in DropReason::ALL) — exporters would omit untouched labels".into(),
        });
    }
    out
}

fn run_all(files: &Files) -> Vec<Violation> {
    let mut out = Vec::new();
    out.extend(check_fences(files));
    out.extend(check_unwraps(files));
    out.extend(check_usage_sync(files));
    out.extend(check_drop_taxonomy(files));
    out.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    out
}

fn collect_tree(root: &Path) -> std::io::Result<Files> {
    let mut files = Files::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        let mut entries: Vec<_> =
            std::fs::read_dir(&dir)?.collect::<Result<_, _>>()?;
        entries.sort_by_key(|e| e.path());
        for e in entries {
            let p = e.path();
            if p.is_dir() {
                stack.push(p);
            } else if p.extension().is_some_and(|x| x == "rs") {
                let rel = p
                    .strip_prefix(root)
                    .unwrap_or(&p)
                    .to_string_lossy()
                    .replace('\\', "/");
                files.push((rel, std::fs::read_to_string(&p)?));
            }
        }
    }
    files.sort_by(|a, b| a.0.cmp(&b.0));
    Ok(files)
}

fn main() {
    let root = std::env::args()
        .nth(1)
        .unwrap_or_else(|| concat!(env!("CARGO_MANIFEST_DIR"), "/src").to_string());
    let files = match collect_tree(Path::new(&root)) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("edgeus-lint: cannot read {root}: {e}");
            std::process::exit(2);
        }
    };
    let violations = run_all(&files);
    for v in &violations {
        println!("{v}");
    }
    if violations.is_empty() {
        println!("edgeus-lint: {} files clean", files.len());
    } else {
        println!("edgeus-lint: {} violation(s)", violations.len());
        std::process::exit(1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tree(items: &[(&str, &str)]) -> Files {
        items.iter().map(|(p, t)| (p.to_string(), t.to_string())).collect()
    }

    #[test]
    fn fence_catches_seeded_allocation() {
        let files = tree(&[(
            "sim/des.rs",
            "fn f() {\n// lint:no-alloc:begin\nlet v = Vec::new();\n// lint:no-alloc:end\n}\n",
        )]);
        let vs = check_fences(&files);
        assert!(vs.iter().any(|v| v.rule == "no-alloc" && v.line == 3), "{vs:?}");
    }

    #[test]
    fn fence_respects_line_escape_and_comments() {
        let files = tree(&[(
            "sim/des.rs",
            "// lint:no-alloc:begin\n// a comment mentioning Vec::new\nlet t = x.clone(); // lint:allow(alloc)\n// lint:no-alloc:end\n",
        )]);
        assert!(check_fences(&files).is_empty());
    }

    #[test]
    fn fence_flags_unbalanced_markers_and_missing_fences() {
        let files = tree(&[
            ("sim/des.rs", "// lint:no-alloc:begin\n"),
            ("coordinator/gus.rs", "fn fill() {}\n"),
        ]);
        let vs = check_fences(&files);
        assert!(vs.iter().any(|v| v.message.contains("unclosed")), "{vs:?}");
        assert!(
            vs.iter().any(|v| v.file == "coordinator/gus.rs"
                && v.message.contains("must carry")),
            "{vs:?}"
        );
    }

    #[test]
    fn unwrap_caught_in_library_code_but_not_tests() {
        let files = tree(&[(
            "coordinator/x.rs",
            "fn f() { y.unwrap(); }\n\
             fn g() { z.lock().unwrap(); }\n\
             fn h() { w.expect(\"boom\"); }\n\
             fn ok() { v.unwrap(); } // lint:allow(unwrap) — reason\n\
             #[cfg(test)]\n\
             mod tests {\n\
                 fn t() { q.unwrap(); }\n\
             }\n",
        )]);
        let vs = check_unwraps(&files);
        assert_eq!(vs.len(), 2, "{vs:?}");
        assert!(vs.iter().all(|v| v.line == 1 || v.line == 3));
    }

    #[test]
    fn usage_sync_catches_undocumented_subcommand() {
        let files = tree(&[(
            "main.rs",
            "fn main() {\n    match sub {\n        Some(\"des\") => cmd_des(&a),\n        Some(\"mystery\") => cmd_mystery(&a),\n    }\n}\nfn print_usage() {\n    eprintln!(\"subcommands:\\n des [--rates]\");\n}\n",
        )]);
        let vs = check_usage_sync(&files);
        assert_eq!(vs.len(), 1, "{vs:?}");
        assert!(vs[0].message.contains("mystery"));
    }

    #[test]
    fn drop_taxonomy_catches_unrecorded_variant() {
        let obs = "pub enum DropReason {\n    A,\n    B,\n}\n\
                   impl DropReason {\n\
                   pub const ALL: [DropReason; 2] = [\n    DropReason::A,\n    DropReason::B,\n];\n\
                   pub fn as_str(self) -> &'static str {\n        match self {\n            DropReason::A => \"a\",\n            DropReason::B => \"b\",\n        }\n    }\n}\n";
        let user =
            "fn f() { m.add(DropReason::A); for reason in DropReason::ALL {} }\n";
        let files = tree(&[("obs/mod.rs", obs), ("sim/des.rs", user)]);
        let vs = check_drop_taxonomy(&files);
        assert_eq!(vs.len(), 1, "{vs:?}");
        assert!(vs[0].message.contains("B is never recorded"));
    }

    #[test]
    fn real_tree_is_clean() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
        let files = collect_tree(&root).expect("read src tree");
        let vs = run_all(&files);
        assert!(
            vs.is_empty(),
            "lint violations in tree:\n{}",
            vs.iter().map(|v| v.to_string()).collect::<Vec<_>>().join("\n")
        );
    }
}
